//! Fleet-scale layer distribution: sharded registry frontends,
//! node-local caches, and DES-scheduled concurrent pulls.
//!
//! The paper's Fig 1 workflow ends with "pull everywhere" — and at HPC
//! scale *everywhere* is thousands of nodes hitting the registry at
//! once.  This module replaces the flat-bandwidth [`Registry::pull`]
//! model with a distribution tier whose mechanisms mirror what real
//! registries (Trow's sharded blob store) and HPC runtimes (Shifter's
//! node-local image cache) do:
//!
//! * [`ShardedRegistry`] — the registry catalogue fronted by `S` shard
//!   frontends, one [`FifoResource`] per shard.  A layer's shard is a
//!   pure function of its content hash, so every client agrees where a
//!   blob lives without coordination, and `N` concurrent pullers
//!   contend realistically per shard instead of sharing one bandwidth
//!   number.  Transfer times come from [`PathCost::registry_wan`].
//! * [`Fleet`] — `N` nodes, each with a content-addressed
//!   [`LayerCache`], connected by an intra-cluster [`Fabric`].
//! * [`Fleet::deploy`] — the DES-scheduled concurrent pull of one image
//!   onto every node.  With [`FanOut::Peer`] (Trow's distribution
//!   model) each layer missing everywhere crosses the WAN **once**,
//!   through its shard, to a seeder node; holders then serve `arity`
//!   siblings per fan-out wave, so the cluster-internal copies ride the
//!   fast fabric and the WAN sees `O(unique layers)` bytes rather than
//!   `O(nodes × layers)`.  [`FanOut::Direct`] is the contention
//!   baseline: every node pulls every missing layer from its shard.
//! * **Fault awareness** — [`Fleet::deploy_with_faults`] threads a
//!   [`FaultSchedule`] through the same wave machinery: WAN transfers
//!   retry under a [`RetryPolicy`] (capped exponential backoff with
//!   [`SimRng`] jitter and a per-transfer timeout), pulls fail over to
//!   surviving registry shards during outage windows
//!   ([`ShardedRegistry::apply_faults`]), fan-out re-parents around
//!   crashed peers, and the report grows
//!   [`retried_bytes`](FleetReport::retried_bytes)/availability
//!   columns instead of assuming every transfer lands.  An empty
//!   schedule is invisible: [`Fleet::deploy`] is the zero-fault
//!   wrapper and stays bit-identical to the fault-free model.
//!
//! A warm re-deploy — every layer already resident in every node cache
//! — transfers zero registry bytes and zero intra-cluster bytes; each
//! node pays only the local per-layer metadata check, which is why the
//! `fig1-scale` figure shows warm makespans orders of magnitude under
//! cold ones.
//!
//! [`Registry::pull`]: super::registry::Registry::pull
//! [`FifoResource`]: crate::des::FifoResource
//! [`PathCost::registry_wan`]: crate::net::PathCost::registry_wan

use std::ops::Range;

use crate::des::{
    Duration, EventQueue, FaultSchedule, FaultStats, FifoResource, QueueStats, SimRng, VirtualTime,
};
use crate::net::{Fabric, PathCost};

use super::cache::{CacheStats, LayerCache};
use super::image::{Image, Layer, LayerId};
use super::lifecycle::Container;
use super::registry::{MissingLayer, PullError, PullReport, Registry};
use super::store::LayerStore;

/// One shard outage window: `(from, until)`; `None` = never recovers.
type OutageWindow = (VirtualTime, Option<VirtualTime>);

/// The registry catalogue fronted by per-shard transfer queues.
///
/// Wraps a [`Registry`] (tags + blobs) and schedules every blob
/// transfer through the [`FifoResource`] frontend owning that blob's
/// content hash, in virtual time.  This is the DES-scheduled
/// replacement for the flat [`Registry::pull`] bandwidth model.
///
/// [`Registry::pull`]: super::registry::Registry::pull
#[derive(Debug)]
pub struct ShardedRegistry {
    registry: Registry,
    shards: Vec<FifoResource>,
    wan: PathCost,
    /// Outage windows per shard, installed by
    /// [`apply_faults`](Self::apply_faults).
    outages: Vec<Vec<OutageWindow>>,
}

/// What one failover-aware transfer submission did.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardAttempt {
    /// A live shard accepted the transfer.
    Served {
        /// Shard that served the transfer (the owner, or a failover
        /// target when the owner was down).
        shard: usize,
        /// Completion instant under FIFO contention on that shard.
        done: VirtualTime,
        /// Whether the owner shard was down and the pull was
        /// re-hashed to a surviving shard.
        failover: bool,
    },
    /// Every shard was inside an outage window at submission time.
    AllDown {
        /// Earliest instant any shard recovers (`None` if no shard
        /// ever does).
        next_up: Option<VirtualTime>,
    },
}

impl ShardedRegistry {
    /// Front `registry` with `shards` single-server WAN frontends
    /// (each with the [`PathCost::registry_wan`] link cost).
    ///
    /// [`PathCost::registry_wan`]: crate::net::PathCost::registry_wan
    pub fn new(registry: Registry, shards: usize) -> Self {
        assert!(shards >= 1, "registry needs at least one shard");
        ShardedRegistry {
            registry,
            shards: vec![FifoResource::new(1); shards],
            wan: PathCost::registry_wan(),
            outages: vec![Vec::new(); shards],
        }
    }

    /// Override the per-shard WAN link cost.
    pub fn with_wan(mut self, wan: PathCost) -> Self {
        self.wan = wan;
        self
    }

    /// The wrapped catalogue (tags, blobs).
    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// Mutable catalogue access (for pushes outside [`push`](Self::push)).
    pub fn registry_mut(&mut self) -> &mut Registry {
        &mut self.registry
    }

    /// Number of shard frontends.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// The per-shard WAN link cost.
    pub fn wan(&self) -> PathCost {
        self.wan
    }

    /// Push an image into the catalogue (instantaneous control-plane
    /// operation; only pulls are scheduled in virtual time here).
    pub fn push(&mut self, image: &Image, source: &LayerStore) -> Result<(), MissingLayer> {
        self.registry.push(image, source)
    }

    /// Install the shard outage windows of `schedule`, replacing any
    /// previous set.  Windows targeting shards this registry does not
    /// have are ignored (schedules are generated against a fleet
    /// config, not a specific registry).
    pub fn apply_faults(&mut self, schedule: &FaultSchedule) {
        self.clear_outages();
        for &(shard, from, until) in schedule.shard_windows() {
            if shard < self.shards.len() {
                self.outages[shard].push((from, until));
            }
        }
    }

    /// Drop all installed outage windows (every shard healthy again).
    pub fn clear_outages(&mut self) {
        for windows in &mut self.outages {
            windows.clear();
        }
    }

    /// Whether `shard` is inside an installed outage window at `t`.
    pub fn shard_down_at(&self, shard: usize, t: VirtualTime) -> bool {
        self.outages[shard].iter().any(|&(from, until)| {
            from <= t
                && match until {
                    None => true,
                    Some(u) => t < u,
                }
        })
    }

    /// Earliest instant at or after `t` when `shard` is up (`None` if
    /// it is inside a window that never closes).
    pub fn shard_next_up(&self, shard: usize, t: VirtualTime) -> Option<VirtualTime> {
        let mut t = t;
        loop {
            let covering = self.outages[shard].iter().find(|&&(from, until)| {
                from <= t
                    && match until {
                        None => true,
                        Some(u) => t < u,
                    }
            });
            match covering {
                None => return Some(t),
                Some(&(_, None)) => return None,
                Some(&(_, Some(u))) => t = u,
            }
        }
    }

    /// Which shard owns `id` — a pure function of the content hash, so
    /// every client agrees without coordination (rendezvous placement,
    /// as in Trow's blob store).
    pub fn shard_of(&self, id: &LayerId) -> usize {
        let take = id.0.len().min(16);
        let h = id
            .0
            .get(..take)
            .and_then(|prefix| u64::from_str_radix(prefix, 16).ok())
            // non-hex ids (hand-built in tests) fall back to a byte fold
            .unwrap_or_else(|| {
                id.0.bytes()
                    .fold(0u64, |acc, b| acc.wrapping_mul(31).wrapping_add(b as u64))
            });
        (h % self.shards.len() as u64) as usize
    }

    /// Schedule the transfer of `bytes` of blob `id` starting no
    /// earlier than `arrival`; returns the completion instant under
    /// FIFO contention on the owning shard.  Ignores outage windows —
    /// the fault-aware path is
    /// [`submit_transfer_failover`](Self::submit_transfer_failover).
    pub fn submit_transfer(
        &mut self,
        arrival: VirtualTime,
        id: &LayerId,
        bytes: u64,
    ) -> VirtualTime {
        let shard = self.shard_of(id);
        let service = self.wan.transfer(bytes);
        self.shards[shard].submit(arrival, service)
    }

    /// Outage-aware transfer submission: the owning shard serves when
    /// up; otherwise the pull re-hashes around the ring to the first
    /// surviving shard (every replica holds the blob — the shards
    /// front one catalogue).  With no outage windows installed this is
    /// byte-identical to [`submit_transfer`](Self::submit_transfer).
    pub fn submit_transfer_failover(
        &mut self,
        arrival: VirtualTime,
        id: &LayerId,
        bytes: u64,
    ) -> ShardAttempt {
        let owner = self.shard_of(id);
        let count = self.shards.len();
        for k in 0..count {
            let shard = (owner + k) % count;
            if self.shard_down_at(shard, arrival) {
                continue;
            }
            let service = self.wan.transfer(bytes);
            let done = self.shards[shard].submit(arrival, service);
            return ShardAttempt::Served {
                shard,
                done,
                failover: k > 0,
            };
        }
        let next_up = (0..count)
            .filter_map(|shard| self.shard_next_up(shard, arrival))
            .min();
        ShardAttempt::AllDown { next_up }
    }

    /// Fetch one blob: returns the layer plus its completion instant.
    pub fn fetch(
        &mut self,
        arrival: VirtualTime,
        id: &LayerId,
    ) -> Result<(Layer, VirtualTime), PullError> {
        let layer = self
            .registry
            .layers
            .get(id)
            .cloned()
            .ok_or_else(|| PullError::CorruptRegistry(id.clone()))?;
        let done = self.submit_transfer(arrival, id, layer.bytes);
        Ok((layer, done))
    }

    /// DES-scheduled single-client pull of `reference` into `dest`
    /// starting at `now`: each missing layer is fetched concurrently
    /// through its shard; the report's `time` is the span until the
    /// last layer lands.  Byte/layer accounting matches the flat
    /// [`Registry::pull`] exactly — only the timing model differs.
    ///
    /// [`Registry::pull`]: super::registry::Registry::pull
    pub fn pull_at(
        &mut self,
        now: VirtualTime,
        reference: &str,
        dest: &mut LayerStore,
    ) -> Result<(Image, PullReport), PullError> {
        let image = self
            .registry
            .image(reference)
            .cloned()
            .ok_or_else(|| PullError::UnknownReference(reference.to_string()))?;
        let missing: Vec<LayerId> = dest.missing(&image.layers).into_iter().cloned().collect();
        let mut bytes = 0u64;
        let mut done_at = now;
        for id in &missing {
            let (layer, done) = self.fetch(now, id)?;
            bytes += layer.bytes;
            done_at = done_at.max(done);
            dest.insert(layer);
        }
        let report = PullReport {
            reference: reference.to_string(),
            layers_transferred: missing.len(),
            layers_reused: image.layers.len() - missing.len(),
            bytes_transferred: bytes,
            time: done_at.since(now),
        };
        Ok((image, report))
    }

    /// Cumulative busy time per shard frontend.
    pub fn shard_busy(&self) -> Vec<Duration> {
        self.shards.iter().map(|s| s.busy_time()).collect()
    }

    /// Queueing delay a request arriving at `at` would see on each
    /// shard frontend (see [`FifoResource::backlog`]) — the saturation
    /// view an open-loop storm reports alongside latency percentiles.
    pub fn shard_backlog(&self, at: VirtualTime) -> Vec<Duration> {
        self.shards.iter().map(|s| s.backlog(at)).collect()
    }

    /// Aggregate WAN drain rate over all shard frontends, in bytes per
    /// second — the capacity an offered-load sweep is calibrated
    /// against (per-request RTT overhead comes on top).
    pub fn aggregate_bandwidth(&self) -> f64 {
        self.wan.beta_bytes_per_sec * self.shards.len() as f64
    }

    /// Per-shard utilisation over `horizon`, counting only service
    /// delivered beyond the `busy_before` snapshot (a prior
    /// [`shard_busy`](Self::shard_busy) result).
    pub fn shard_utilisation(&self, busy_before: &[Duration], horizon: Duration) -> Vec<f64> {
        self.shards
            .iter()
            .zip(busy_before)
            .map(|(s, &b)| s.utilisation(b, horizon))
            .collect()
    }

    /// Forget all shard queue state (fresh deployment campaign).
    /// Installed outage windows are kept — they belong to the fault
    /// schedule, not the queues; see
    /// [`clear_outages`](Self::clear_outages).
    pub fn reset_clocks(&mut self) {
        for s in &mut self.shards {
            s.reset();
        }
    }
}

/// How layers spread inside the cluster once a copy exists there.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FanOut {
    /// Every node fetches every missing layer from the registry shard
    /// itself — the no-dedup baseline that exposes WAN contention
    /// (`O(nodes × layers)` registry bytes).
    Direct,
    /// Trow-style peer distribution: the first puller seeds the layer
    /// over the WAN (once per layer, through its shard), then every
    /// holder serves `arity` sibling nodes per fan-out wave over the
    /// cluster fabric — holders grow geometrically, so full coverage
    /// takes `O(log nodes)` waves.
    Peer {
        /// Siblings each holder serves per wave (≥ 1).
        arity: usize,
    },
}

/// Retry discipline for fault-aware transfers: capped exponential
/// backoff with deterministic [`SimRng`] jitter plus an optional
/// per-transfer timeout.
///
/// A transfer that starts inside a WAN drop window is lost and backed
/// off *blindly* (the client cannot sense the window), so a long
/// enough window exhausts `max_attempts` and the target is reported
/// permanently failed rather than retried forever.  When every
/// registry shard is down the front door *can* publish a recovery
/// instant, so those retries aim at `max(recovery, backoff)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RetryPolicy {
    /// Total attempts per transfer, the first included (≥ 1).
    pub max_attempts: u32,
    /// Backoff before the first retry; doubles each further retry.
    pub base_backoff: Duration,
    /// Ceiling the exponential backoff saturates at.
    pub max_backoff: Duration,
    /// Multiplicative jitter half-width applied to each backoff
    /// (`0.2` = ±20%); `0.0` draws nothing from the rng stream.
    pub jitter: f64,
    /// Abandon a transfer whose completion lies further than this
    /// beyond its start (`None` = wait forever).
    pub timeout: Option<Duration>,
}

impl RetryPolicy {
    /// No retries at all: one attempt, no backoff, no timeout.  The
    /// policy [`Fleet::deploy`] runs with — it never consults the rng,
    /// which keeps the fault-free path bit-identical.
    pub fn none() -> Self {
        RetryPolicy {
            max_attempts: 1,
            base_backoff: Duration::ZERO,
            max_backoff: Duration::ZERO,
            jitter: 0.0,
            timeout: None,
        }
    }

    /// The deployment-campaign default: 6 attempts, 50 ms base backoff
    /// doubling to a 5 s cap, ±20% jitter, 5-minute per-transfer
    /// timeout.
    pub fn hpc() -> Self {
        RetryPolicy {
            max_attempts: 6,
            base_backoff: Duration::from_millis(50),
            max_backoff: Duration::from_secs_f64(5.0),
            jitter: 0.2,
            timeout: Some(Duration::from_secs_f64(300.0)),
        }
    }

    /// Backoff before attempt `attempt` (attempt 1 is the first try,
    /// so its "backoff" is the base; attempt `k` waits
    /// `base × 2^(k-1)`, saturating at [`max_backoff`]).  Jitter is
    /// drawn from `rng` only when one is supplied and
    /// [`jitter`](Self::jitter) is non-zero.
    ///
    /// [`max_backoff`]: Self::max_backoff
    pub fn backoff(&self, attempt: u32, rng: Option<&mut SimRng>) -> Duration {
        let exp = attempt.saturating_sub(1).min(20);
        let capped = Duration::from_nanos(
            self.base_backoff
                .as_nanos()
                .saturating_mul(1u64 << exp)
                .min(self.max_backoff.as_nanos()),
        );
        match rng {
            Some(r) if self.jitter > 0.0 => capped.scale(r.jitter(self.jitter)),
            _ => capped,
        }
    }
}

/// Static description of a deployment fleet.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// Number of compute nodes pulling the image.
    pub nodes: usize,
    /// Intra-cluster distribution strategy.
    pub fan_out: FanOut,
    /// Per-node layer-cache capacity in bytes (`u64::MAX` = unbounded).
    pub cache_capacity_bytes: u64,
    /// Fabric carrying intra-cluster fan-out hops.
    pub fabric: Fabric,
    /// Local metadata check a node pays per image layer on every
    /// deploy, hit or miss (the `shifterimg`-style verify/mount cost —
    /// what a fully warm deploy still costs).
    pub per_layer_check: Duration,
}

impl FleetConfig {
    /// An Edison-like deployment target: Aries fabric, binary peer
    /// fan-out, unbounded node caches, 2 ms local metadata check per
    /// layer.  (The registry shard count lives on the
    /// [`ShardedRegistry`] the fleet pulls through.)
    pub fn hpc(nodes: usize) -> Self {
        FleetConfig {
            nodes,
            fan_out: FanOut::Peer { arity: 2 },
            cache_capacity_bytes: u64::MAX,
            fabric: Fabric::aries(),
            per_layer_check: Duration::from_millis(2),
        }
    }
}

/// What one fleet deployment did (the fleet analogue of [`PullReport`]).
#[derive(Debug, Clone, PartialEq)]
pub struct FleetReport {
    /// Image reference deployed.
    pub reference: String,
    /// Nodes targeted by this wave (the deploy scope).
    pub nodes: usize,
    /// Layers in the image (with duplicates, if any).
    pub layers_total: usize,
    /// Distinct layers considered for transfer.
    pub unique_layers: usize,
    /// WAN transfers performed (shard → cluster), lost attempts
    /// included.
    pub wan_transfers: usize,
    /// Bytes that crossed the WAN from registry shards.
    pub wan_bytes: u64,
    /// Bytes copied node-to-node inside the cluster.
    pub intra_bytes: u64,
    /// Bytes that crossed a link but never landed in a cache: WAN
    /// attempts lost to drop windows or timeouts, plus copies that
    /// arrived while their target node was down.  The conservation
    /// invariant is `total_bytes() == bytes admitted + retried_bytes`
    /// (for unbounded caches).
    pub retried_bytes: u64,
    /// Transfer re-attempts scheduled (WAN retries + re-deliveries).
    pub retries: u64,
    /// Pulls re-hashed to a surviving shard during an outage.
    pub failovers: u64,
    /// Scope nodes newly given up on this wave (crashed and never
    /// rejoining, or out of retry budget).
    pub permanently_failed: usize,
    /// Virtual instant the deployment started.
    pub started_at: VirtualTime,
    /// Span from start until the slowest node finished (transfers +
    /// per-layer local checks).
    pub makespan: Duration,
    /// Cache accounting for this wave only (summed over nodes).
    pub cache: CacheStats,
    /// Per-shard utilisation over the makespan (busy / makespan).
    pub shard_utilisation: Vec<f64>,
    /// Containers created and started on the fleet after the pull.
    pub containers_started: usize,
    /// Fault accounting: injected side from the schedule's windows,
    /// reaction side from this wave's counters.  All-zero for a
    /// fault-free wave.
    pub fault: FaultStats,
    /// Calendar-queue counters of the wave's transfer scheduler (one
    /// ready event per node per transferred layer; a fully warm
    /// re-deploy schedules none).  See `des::stats`.
    pub queue: QueueStats,
}

impl FleetReport {
    /// All bytes moved anywhere: WAN plus intra-cluster.
    pub fn total_bytes(&self) -> u64 {
        self.wan_bytes + self.intra_bytes
    }

    /// Bytes that actually landed in a node cache:
    /// [`total_bytes`](Self::total_bytes) minus the wasted
    /// [`retried_bytes`](Self::retried_bytes).
    pub fn delivered_bytes(&self) -> u64 {
        self.total_bytes().saturating_sub(self.retried_bytes)
    }

    /// Fleet availability over this wave's makespan:
    /// `1 - downtime / (nodes × makespan)` (see
    /// [`FaultStats::availability`]).
    pub fn availability(&self) -> f64 {
        self.fault.availability(self.nodes, self.makespan)
    }

    /// One-paragraph trace line for CLI output.  Fault-free waves
    /// render exactly as before; the retry/failover tail appears only
    /// when something went wrong.
    pub fn render(&self) -> String {
        let mut text = format!(
            "deploy {} -> {} nodes: makespan {}, WAN {:.1} MB in {} transfer(s), \
             intra-cluster {:.1} MB, cache hit rate {:.0}%, shard util {}, \
             {} ready events (queue depth hwm {})",
            self.reference,
            self.nodes,
            self.makespan,
            self.wan_bytes as f64 / 1e6,
            self.wan_transfers,
            self.intra_bytes as f64 / 1e6,
            self.cache.hit_rate() * 100.0,
            self.shard_utilisation
                .iter()
                .map(|u| format!("{:.0}%", u * 100.0))
                .collect::<Vec<_>>()
                .join("/"),
            self.queue.pushes,
            self.queue.depth_hwm,
        );
        if self.retries != 0
            || self.failovers != 0
            || self.retried_bytes != 0
            || self.permanently_failed != 0
        {
            text.push_str(&format!(
                ", {} retry(ies), {} failover(s), {:.1} MB re-sent, \
                 {} node(s) permanently failed, availability {:.4}",
                self.retries,
                self.failovers,
                self.retried_bytes as f64 / 1e6,
                self.permanently_failed,
                self.availability(),
            ));
        }
        text
    }
}

/// Reaction-side counters one fault-aware wave accumulates.
#[derive(Default)]
struct FaultAccum {
    wan_bytes: u64,
    wan_transfers: usize,
    retried_bytes: u64,
    retries: u64,
    failovers: u64,
    transfers_dropped: u64,
}

/// Borrowed fault context threaded through one deployment wave; its
/// methods keep the retry loops (and their accounting) in one place.
struct WaveCtx<'a> {
    faults: &'a FaultSchedule,
    policy: &'a RetryPolicy,
    rng: &'a mut SimRng,
    acc: FaultAccum,
}

impl WaveCtx<'_> {
    /// One WAN transfer of `bytes` of `id` starting no earlier than
    /// `start`, with shard failover plus drop-window/timeout retries
    /// under the policy.  Returns the completion instant of the first
    /// surviving attempt, or `None` once the retry budget is spent
    /// (or no shard ever recovers).
    fn wan(
        &mut self,
        registry: &mut ShardedRegistry,
        id: &LayerId,
        bytes: u64,
        start: VirtualTime,
    ) -> Option<VirtualTime> {
        let mut at = start;
        let mut attempt = 1u32;
        loop {
            match registry.submit_transfer_failover(at, id, bytes) {
                ShardAttempt::Served { done, failover, .. } => {
                    self.acc.wan_bytes += bytes;
                    self.acc.wan_transfers += 1;
                    if failover {
                        self.acc.failovers += 1;
                    }
                    // a transfer started inside a drop window is lost;
                    // one running past the per-transfer timeout is
                    // abandoned at start + timeout
                    let lost = self.faults.drop_until(at).is_some();
                    let gave_up_at = match self.policy.timeout {
                        Some(limit) if !lost && done.since(at) > limit => Some(at + limit),
                        _ => None,
                    };
                    if !lost && gave_up_at.is_none() {
                        return Some(done);
                    }
                    self.acc.retried_bytes += bytes;
                    self.acc.transfers_dropped += 1;
                    if attempt >= self.policy.max_attempts {
                        return None;
                    }
                    attempt += 1;
                    self.acc.retries += 1;
                    // the client cannot sense a drop window, so a lost
                    // transfer backs off blindly; a timeout is only
                    // known once the limit fires
                    let pause = self.policy.backoff(attempt, Some(&mut *self.rng));
                    at = match gave_up_at {
                        Some(abandoned) => abandoned + pause,
                        None => at + pause,
                    };
                }
                ShardAttempt::AllDown { next_up } => {
                    let up = next_up?;
                    if attempt >= self.policy.max_attempts {
                        return None;
                    }
                    attempt += 1;
                    self.acc.retries += 1;
                    // the registry front door redirects, so this retry
                    // can aim at the published recovery instant
                    let pause = self.policy.backoff(attempt, Some(&mut *self.rng));
                    at = up.max(at + pause);
                }
            }
        }
    }

    /// Direct-mode delivery to one node: WAN transfer, then re-pull
    /// whenever the bytes arrive while the node is down.  `None` =
    /// the node (or the registry) is a lost cause.
    fn deliver_direct(
        &mut self,
        registry: &mut ShardedRegistry,
        id: &LayerId,
        bytes: u64,
        node: usize,
        start: VirtualTime,
    ) -> Option<VirtualTime> {
        let mut done = self.wan(registry, id, bytes, start)?;
        loop {
            match self.faults.node_next_up(node, done) {
                Some(up) if up == done => return Some(done),
                Some(up) => {
                    // arrived while the node was down: wasted transfer,
                    // pull again once it rejoins
                    self.acc.retried_bytes += bytes;
                    self.acc.retries += 1;
                    done = self.wan(registry, id, bytes, up)?;
                }
                None => {
                    self.acc.retried_bytes += bytes;
                    return None;
                }
            }
        }
    }
}

/// `N` nodes with node-local layer caches, deploying images pulled
/// through a [`ShardedRegistry`].  Successive [`deploy`](Fleet::deploy)
/// calls share the caches (that is the point: the second deploy is
/// warm) and advance the fleet's virtual clock.
#[derive(Debug)]
pub struct Fleet {
    config: FleetConfig,
    caches: Vec<LayerCache>,
    containers: Vec<Container>,
    clock: VirtualTime,
    next_container_id: u64,
    /// Nodes given up on by a previous fault-injected wave.
    dead: Vec<bool>,
    /// Latest wave start whose eviction storms have been applied
    /// (`None` = no wave ran yet); keeps each storm a one-shot.
    storm_mark: Option<VirtualTime>,
}

impl Fleet {
    /// A cold fleet (every node cache empty) at virtual time zero.
    pub fn new(config: FleetConfig) -> Self {
        assert!(config.nodes >= 1, "fleet needs at least one node");
        if let FanOut::Peer { arity } = config.fan_out {
            assert!(arity >= 1, "peer fan-out needs arity >= 1");
        }
        let caches = (0..config.nodes)
            .map(|_| LayerCache::new(config.cache_capacity_bytes))
            .collect();
        let dead = vec![false; config.nodes];
        Fleet {
            config,
            caches,
            containers: Vec::new(),
            clock: VirtualTime::ZERO,
            next_container_id: 0,
            dead,
            storm_mark: None,
        }
    }

    /// The fleet's configuration.
    pub fn config(&self) -> &FleetConfig {
        &self.config
    }

    /// Node-local caches, indexed by node.
    pub fn caches(&self) -> &[LayerCache] {
        &self.caches
    }

    /// Mutable cache access (tests pre-warm subsets of the fleet).
    pub fn caches_mut(&mut self) -> &mut [LayerCache] {
        &mut self.caches
    }

    /// Containers created by the most recent deployment wave.
    pub fn containers(&self) -> &[Container] {
        &self.containers
    }

    /// The fleet's virtual clock (advances with each deploy wave).
    pub fn now(&self) -> VirtualTime {
        self.clock
    }

    /// Per-node permanent-failure flags (`true` = given up on by a
    /// previous fault-injected wave; the node takes no further part
    /// in deployments).
    pub fn failed_nodes(&self) -> &[bool] {
        &self.dead
    }

    /// Sum of every node cache's lifetime counters.
    pub fn cache_totals(&self) -> CacheStats {
        let mut total = CacheStats::default();
        for c in &self.caches {
            total.merge(&c.stats());
        }
        total
    }

    /// Deploy `reference` onto every node concurrently, in virtual
    /// time: consult each node cache, seed cache-missing layers from
    /// the owning registry shard, fan copies out across the cluster
    /// fabric, admit them into the node caches, then create and start
    /// one container per node.  Returns the wave's [`FleetReport`].
    ///
    /// This is the fault-free wrapper around
    /// [`deploy_with_faults`](Self::deploy_with_faults): empty
    /// schedule, [`RetryPolicy::none`], full node scope — and the rng
    /// stream is never consulted, so reports are bit-identical to the
    /// pre-fault model.
    pub fn deploy(
        &mut self,
        registry: &mut ShardedRegistry,
        reference: &str,
    ) -> Result<FleetReport, PullError> {
        let nodes = self.config.nodes;
        let mut rng = SimRng::new(0, "fault-free");
        self.deploy_with_faults(
            registry,
            reference,
            0..nodes,
            &FaultSchedule::none(),
            &RetryPolicy::none(),
            &mut rng,
        )
    }

    /// Deploy `reference` onto the nodes in `scope` under a fault
    /// schedule and retry policy.
    ///
    /// Semantics on top of the fault-free wave:
    ///
    /// * **Eviction storms** at or before the wave start shed bytes
    ///   from the struck node's cache before lookups run (each storm
    ///   fires once across a campaign).
    /// * **WAN transfers** go through [`WaveCtx::wan`]: shard
    ///   failover, drop-window/timeout loss, capped backoff retries.
    /// * **Crashed nodes**: a copy arriving during a down window is
    ///   wasted (`retried_bytes`) and re-sent after the rejoin — from
    ///   a live holder over the fabric when one exists, else from the
    ///   registry.  Nodes that never rejoin (or exhaust the retry
    ///   budget) are marked permanently failed, skipped by later
    ///   waves, and reported in
    ///   [`permanently_failed`](FleetReport::permanently_failed).
    /// * **Scope** restricts which nodes deploy (rolling upgrades
    ///   target rings); caches and failure flags are fleet-wide, so
    ///   nodes outside the scope still serve as fan-out holders.
    ///
    /// Every retry loop either consumes retry budget or strictly
    /// advances virtual time past a finite fault window, so the wave
    /// always terminates: each scope node ends deployed or is
    /// reported permanently failed.
    pub fn deploy_with_faults(
        &mut self,
        registry: &mut ShardedRegistry,
        reference: &str,
        scope: Range<usize>,
        faults: &FaultSchedule,
        policy: &RetryPolicy,
        rng: &mut SimRng,
    ) -> Result<FleetReport, PullError> {
        let t0 = self.clock;
        let n = self.config.nodes;
        assert!(!scope.is_empty(), "deploy scope must name at least one node");
        assert!(scope.end <= n, "deploy scope exceeds the fleet");
        assert!(policy.max_attempts >= 1, "retry policy needs one attempt");
        let image = registry
            .registry()
            .image(reference)
            .cloned()
            .ok_or_else(|| PullError::UnknownReference(reference.to_string()))?;

        // distinct layers, first-appearance order (image stacks are
        // normally duplicate-free; dedup keeps the accounting honest)
        let mut unique: Vec<&LayerId> = Vec::new();
        for id in &image.layers {
            if !unique.contains(&id) {
                unique.push(id);
            }
        }

        let stats_before = self.cache_totals();
        // eviction storms that struck since the last wave land before
        // this wave's lookups, so the cache delta shows the damage
        let mark = self.storm_mark;
        for &(at, node, bytes) in faults.evict_storms() {
            let fresh = at <= t0
                && match mark {
                    None => true,
                    Some(m) => at > m,
                };
            if fresh && node < n {
                self.caches[node].shed(bytes);
            }
        }
        self.storm_mark = Some(t0);

        let busy_before = registry.shard_busy();
        let mut failed = self.dead.clone();
        let mut ctx = WaveCtx {
            faults,
            policy,
            rng,
            acc: FaultAccum::default(),
        };
        let mut intra_bytes = 0u64;
        // instant each node has all its layers (before local checks)
        let mut node_ready = vec![t0; n];
        // every transfer-completion instant is scheduled through one
        // calendar queue (fan-out waves enter as batches) and drained
        // in time order at the end of its layer, so the depth
        // high-water mark in the report is the peak of concurrently
        // in-flight completions, not a lifetime push count
        let mut sched: EventQueue<usize> = EventQueue::with_capacity(scope.len());

        for &id in &unique {
            let mut needers: Vec<usize> = Vec::new();
            for node in scope.clone() {
                if failed[node] {
                    continue;
                }
                if self.caches[node].lookup(id).is_none() {
                    needers.push(node);
                }
            }
            if needers.is_empty() {
                continue; // fully warm layer: no transfer anywhere
            }
            // node caches hold the blob (id + bytes + provenance), not
            // the file manifest — that stays in the catalogue, exactly
            // as a compressed blob cache on a real node would
            let blob = registry
                .registry()
                .layers
                .get(id)
                .ok_or_else(|| PullError::CorruptRegistry(id.clone()))?
                .blob();

            match self.config.fan_out {
                FanOut::Direct => {
                    let mut arrivals = Vec::with_capacity(needers.len());
                    for &node in &needers {
                        match ctx.deliver_direct(registry, id, blob.bytes, node, t0) {
                            Some(done) => {
                                arrivals.push((done, node));
                                self.caches[node].admit(blob.clone());
                            }
                            None => failed[node] = true,
                        }
                    }
                    sched.push_batch(arrivals);
                }
                FanOut::Peer { arity } => {
                    // live holders anywhere in the fleet can serve the
                    // fan-out, scope or not
                    let mut holder_nodes: Vec<usize> = (0..n)
                        .filter(|&node| !failed[node] && self.caches[node].contains(id))
                        .collect();

                    let (start, rest) = if holder_nodes.is_empty() {
                        // no holder anywhere: seed one copy over the
                        // WAN onto the first needer that is (or comes
                        // back) up
                        let mut remaining = needers.clone();
                        let mut seed: Option<(usize, VirtualTime)> = None;
                        let mut t_seed = t0;
                        while seed.is_none() && !remaining.is_empty() {
                            // earliest-available candidate; prune ones
                            // that never rejoin
                            let mut best: Option<(usize, VirtualTime)> = None;
                            let mut dead_idx: Vec<usize> = Vec::new();
                            for (idx, &node) in remaining.iter().enumerate() {
                                match ctx.faults.node_next_up(node, t_seed) {
                                    None => dead_idx.push(idx),
                                    Some(up) => {
                                        let better = match best {
                                            None => true,
                                            Some((_, b)) => up < b,
                                        };
                                        if better {
                                            best = Some((idx, up));
                                        }
                                    }
                                }
                            }
                            for &idx in dead_idx.iter().rev() {
                                let node = remaining.remove(idx);
                                failed[node] = true;
                                if let Some((b, _)) = best.as_mut() {
                                    if *b > idx {
                                        *b -= 1;
                                    }
                                }
                            }
                            let Some((idx, up)) = best else { break };
                            match ctx.wan(registry, id, blob.bytes, up) {
                                None => {
                                    // registry unreachable for good (or
                                    // budget spent): nobody in scope can
                                    // get this layer
                                    for node in remaining.drain(..) {
                                        failed[node] = true;
                                    }
                                    break;
                                }
                                Some(done) => {
                                    if ctx.faults.node_down_at(remaining[idx], done) {
                                        // seed arrived mid-crash: wasted
                                        ctx.acc.retried_bytes += blob.bytes;
                                        match ctx.faults.node_next_up(remaining[idx], done) {
                                            Some(up2) => {
                                                ctx.acc.retries += 1;
                                                t_seed = up2;
                                            }
                                            None => {
                                                let node = remaining.remove(idx);
                                                failed[node] = true;
                                            }
                                        }
                                    } else {
                                        seed = Some((idx, done));
                                    }
                                }
                            }
                        }
                        let Some((idx, done)) = seed else {
                            // every candidate died or the registry was
                            // unreachable: layer undeliverable in scope
                            continue;
                        };
                        let seeder = remaining.remove(idx);
                        sched.push(done, seeder);
                        self.caches[seeder].admit(blob.clone());
                        holder_nodes.push(seeder);
                        (done, remaining)
                    } else {
                        (t0, needers.clone())
                    };

                    let hop = self.config.fabric.p2p(blob.bytes, false);
                    let mut served = 0usize;
                    let mut t = start;
                    let mut resend: Vec<(VirtualTime, usize)> = Vec::new();
                    while served < rest.len() {
                        let live = holder_nodes
                            .iter()
                            .filter(|&&h| !ctx.faults.node_down_at(h, t))
                            .count();
                        if live == 0 {
                            // every holder is down: wait for the first
                            // rejoin, or fall back to the registry for
                            // everyone still waiting
                            let next = holder_nodes
                                .iter()
                                .filter_map(|&h| ctx.faults.node_next_up(h, t))
                                .min();
                            match next {
                                Some(up) => {
                                    t = up;
                                }
                                None => {
                                    for &node in &rest[served..] {
                                        ctx.acc.retries += 1;
                                        resend.push((t, node));
                                    }
                                    served = rest.len();
                                }
                            }
                            continue;
                        }
                        let wave = (live * arity).min(rest.len() - served);
                        t += hop;
                        let mut arrivals = Vec::with_capacity(wave);
                        for &node in &rest[served..served + wave] {
                            intra_bytes += blob.bytes;
                            if ctx.faults.node_down_at(node, t) {
                                // copy arrived mid-crash: wasted hop
                                ctx.acc.retried_bytes += blob.bytes;
                                if ctx.faults.node_next_up(node, t).is_some() {
                                    ctx.acc.retries += 1;
                                    resend.push((t, node));
                                } else {
                                    failed[node] = true;
                                }
                            } else {
                                arrivals.push((t, node));
                                self.caches[node].admit(blob.clone());
                                holder_nodes.push(node);
                            }
                        }
                        sched.push_batch(arrivals);
                        served += wave;
                    }

                    // second pass: nodes that were down when their copy
                    // arrived re-pull once they rejoin — from a live
                    // holder over the fabric when one exists, else from
                    // the registry
                    for (when, node) in resend {
                        if failed[node] {
                            continue;
                        }
                        let mut when = when;
                        loop {
                            let Some(up) = ctx.faults.node_next_up(node, when) else {
                                failed[node] = true;
                                break;
                            };
                            let src_live = holder_nodes
                                .iter()
                                .any(|&h| !ctx.faults.node_down_at(h, up));
                            let arrival = if src_live {
                                intra_bytes += blob.bytes;
                                up + hop
                            } else {
                                match ctx.wan(registry, id, blob.bytes, up) {
                                    Some(done) => done,
                                    None => {
                                        failed[node] = true;
                                        break;
                                    }
                                }
                            };
                            if ctx.faults.node_down_at(node, arrival) {
                                ctx.acc.retried_bytes += blob.bytes;
                                ctx.acc.retries += 1;
                                when = arrival;
                                continue;
                            }
                            sched.push(arrival, node);
                            self.caches[node].admit(blob.clone());
                            holder_nodes.push(node);
                            break;
                        }
                    }
                }
            }

            // drain this layer's completions in time order; a node's
            // readiness is its last event across all layers
            while let Some((ready, node)) = sched.pop() {
                node_ready[node] = node_ready[node].max(ready);
            }
        }
        let queue = sched.stats();

        // local per-layer verify/mount, then create + start a container
        // on every surviving node in scope
        let check = self.config.per_layer_check * image.layers.len() as u64;
        self.containers.clear();
        let mut finish = t0;
        let mut started = 0usize;
        for node in scope.clone() {
            if failed[node] {
                continue;
            }
            let done = node_ready[node] + check;
            finish = finish.max(done);
            let mut c = Container::create(self.next_container_id, image.id.clone(), done);
            self.next_container_id += 1;
            c.start(done).expect("fresh container starts");
            self.containers.push(c);
            started += 1;
        }
        let makespan = finish.since(t0);
        self.clock = finish;

        let shard_utilisation = registry.shard_utilisation(&busy_before, makespan);

        let newly_failed = failed.iter().filter(|&&f| f).count()
            - self.dead.iter().filter(|&&f| f).count();
        self.dead = failed;
        let mut fault = faults.stats_over(t0, finish);
        fault.retries = ctx.acc.retries;
        fault.failovers = ctx.acc.failovers;
        fault.transfers_dropped = ctx.acc.transfers_dropped;
        fault.permanent_failures = newly_failed as u64;

        Ok(FleetReport {
            reference: reference.to_string(),
            nodes: scope.len(),
            layers_total: image.layers.len(),
            unique_layers: unique.len(),
            wan_transfers: ctx.acc.wan_transfers,
            wan_bytes: ctx.acc.wan_bytes,
            intra_bytes,
            retried_bytes: ctx.acc.retried_bytes,
            retries: ctx.acc.retries,
            failovers: ctx.acc.failovers,
            permanently_failed: newly_failed,
            started_at: t0,
            makespan,
            cache: self.cache_totals().since(&stats_before),
            shard_utilisation,
            containers_started: started,
            fault,
            queue,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::buildfile::Buildfile;
    use crate::container::builder::Builder;
    use crate::des::Fault;

    fn registry_with(reference: &str, text: &str) -> (ShardedRegistry, u64, usize) {
        let mut store = LayerStore::new();
        let image = Builder::new()
            .build(&Buildfile::parse(text).unwrap(), reference, &mut store)
            .unwrap()
            .image;
        let bytes = image.size_bytes(&store);
        let layers = image.layers.len();
        let mut reg = Registry::new();
        reg.push(&image, &store).unwrap();
        (ShardedRegistry::new(reg, 4), bytes, layers)
    }

    #[test]
    fn shard_of_is_deterministic_and_in_range() {
        let (reg, _, _) = registry_with("a:1", "FROM ubuntu:16.04\nRUN echo x");
        for id in reg.registry().layers.ids().cloned().collect::<Vec<_>>() {
            let s = reg.shard_of(&id);
            assert!(s < reg.shard_count());
            assert_eq!(s, reg.shard_of(&id));
        }
        // non-hex ids use the fallback fold and stay in range
        assert!(reg.shard_of(&LayerId("not-hex!".into())) < 4);
    }

    #[test]
    fn pull_at_matches_flat_pull_accounting() {
        let (mut sharded, bytes, layers) = registry_with("a:1", "FROM ubuntu:16.04\nRUN echo x");
        let mut dest = LayerStore::new();
        let (_, report) = sharded
            .pull_at(VirtualTime::ZERO, "a:1", &mut dest)
            .unwrap();
        assert_eq!(report.layers_transferred, layers);
        assert_eq!(report.bytes_transferred, bytes);
        assert!(report.time > Duration::ZERO);
        assert_eq!(dest.len(), layers);
        // re-pull into the same store: nothing to move
        let (_, again) = sharded
            .pull_at(VirtualTime::ZERO, "a:1", &mut dest)
            .unwrap();
        assert_eq!(again.layers_transferred, 0);
        assert_eq!(again.bytes_transferred, 0);
        assert_eq!(again.time, Duration::ZERO);
    }

    #[test]
    fn backlog_and_bandwidth_views() {
        let (mut sharded, _, _) = registry_with("a:1", "FROM alpine:3.4");
        let wan = sharded.wan();
        assert_eq!(sharded.aggregate_bandwidth(), wan.beta_bytes_per_sec * 4.0);
        assert!(
            sharded
                .shard_backlog(VirtualTime::ZERO)
                .iter()
                .all(|&b| b == Duration::ZERO),
            "idle shards have no backlog"
        );
        let id = sharded
            .registry()
            .layers
            .ids()
            .next()
            .cloned()
            .expect("image has layers");
        let shard = sharded.shard_of(&id);
        let done = sharded.submit_transfer(VirtualTime::ZERO, &id, 64_000_000);
        let backlog = sharded.shard_backlog(VirtualTime::ZERO);
        assert_eq!(backlog[shard], done.since(VirtualTime::ZERO));
        for (s, &b) in backlog.iter().enumerate() {
            if s != shard {
                assert_eq!(b, Duration::ZERO, "other shards stay idle");
            }
        }
    }

    #[test]
    fn concurrent_pulls_contend_per_shard() {
        let (mut sharded, _, _) = registry_with("a:1", "FROM alpine:3.4");
        let mut d1 = LayerStore::new();
        let mut d2 = LayerStore::new();
        let (_, r1) = sharded.pull_at(VirtualTime::ZERO, "a:1", &mut d1).unwrap();
        let (_, r2) = sharded.pull_at(VirtualTime::ZERO, "a:1", &mut d2).unwrap();
        // same arrival, same single-layer shard queue: the second
        // client queues behind the first
        assert!(r2.time > r1.time, "{:?} !> {:?}", r2.time, r1.time);
    }

    #[test]
    fn unknown_reference_errors() {
        let (mut sharded, _, _) = registry_with("a:1", "FROM alpine:3.4");
        assert!(matches!(
            sharded.pull_at(VirtualTime::ZERO, "ghost:1", &mut LayerStore::new()),
            Err(PullError::UnknownReference(_))
        ));
        let mut fleet = Fleet::new(FleetConfig::hpc(2));
        assert!(matches!(
            fleet.deploy(&mut sharded, "ghost:1"),
            Err(PullError::UnknownReference(_))
        ));
    }

    #[test]
    fn peer_deploy_wan_bytes_are_unique_layers_once() {
        let (mut sharded, bytes, layers) = registry_with("a:1", "FROM ubuntu:16.04\nRUN echo x");
        let n = 64;
        let mut fleet = Fleet::new(FleetConfig::hpc(n));
        let cold = fleet.deploy(&mut sharded, "a:1").unwrap();
        assert_eq!(cold.unique_layers, layers);
        assert_eq!(cold.wan_transfers, layers, "each layer seeded once");
        assert_eq!(cold.wan_bytes, bytes, "each layer crossed the WAN once");
        assert_eq!(cold.intra_bytes, bytes * (n as u64 - 1), "fan-out copies");
        assert_eq!(cold.cache.misses, (n * layers) as u64);
        assert_eq!(cold.cache.hits, 0);
        assert_eq!(cold.containers_started, n);
        assert!(cold.makespan > Duration::ZERO);
    }

    #[test]
    fn warm_redeploy_moves_zero_bytes() {
        let (mut sharded, _, layers) = registry_with("a:1", "FROM ubuntu:16.04\nRUN echo x");
        let mut fleet = Fleet::new(FleetConfig::hpc(128));
        let cold = fleet.deploy(&mut sharded, "a:1").unwrap();
        let warm = fleet.deploy(&mut sharded, "a:1").unwrap();
        assert_eq!(warm.wan_bytes, 0);
        assert_eq!(warm.intra_bytes, 0);
        assert_eq!(warm.wan_transfers, 0);
        assert_eq!(warm.cache.hits, (128 * layers) as u64);
        assert_eq!(warm.cache.misses, 0);
        // warm cost is only the local per-layer checks
        assert_eq!(warm.makespan, Duration::from_millis(2) * layers as u64);
        assert!(warm.makespan.as_secs_f64() < 0.1 * cold.makespan.as_secs_f64());
        assert!(warm.started_at > cold.started_at, "clock advanced");
    }

    #[test]
    fn direct_deploy_pays_wan_per_node() {
        let (mut sharded, bytes, layers) = registry_with("a:1", "FROM alpine:3.4\nRUN echo x");
        let n = 16;
        let mut cfg = FleetConfig::hpc(n);
        cfg.fan_out = FanOut::Direct;
        let mut fleet = Fleet::new(cfg);
        let cold = fleet.deploy(&mut sharded, "a:1").unwrap();
        assert_eq!(cold.wan_bytes, bytes * n as u64);
        assert_eq!(cold.wan_transfers, layers * n);
        assert_eq!(cold.intra_bytes, 0);
    }

    #[test]
    fn direct_contention_grows_with_fleet_size() {
        let make = |n: usize| {
            let (mut sharded, _, _) = registry_with("a:1", "FROM alpine:3.4");
            let mut cfg = FleetConfig::hpc(n);
            cfg.fan_out = FanOut::Direct;
            let mut fleet = Fleet::new(cfg);
            fleet.deploy(&mut sharded, "a:1").unwrap().makespan
        };
        let small = make(8);
        let large = make(64);
        assert!(
            large.as_secs_f64() > 4.0 * small.as_secs_f64(),
            "direct pulls serialise on the shards: {small} vs {large}"
        );
    }

    #[test]
    fn peer_beats_direct_at_scale() {
        let run = |fan_out| {
            let (mut sharded, _, _) = registry_with("a:1", "FROM ubuntu:16.04\nRUN echo x");
            let mut cfg = FleetConfig::hpc(256);
            cfg.fan_out = fan_out;
            let mut fleet = Fleet::new(cfg);
            fleet.deploy(&mut sharded, "a:1").unwrap().makespan
        };
        let peer = run(FanOut::Peer { arity: 2 });
        let direct = run(FanOut::Direct);
        assert!(
            peer.as_secs_f64() < direct.as_secs_f64() / 4.0,
            "peer {peer} should be far under direct {direct}"
        );
    }

    #[test]
    fn prewarmed_holders_skip_the_wan() {
        let (mut sharded, bytes, _) = registry_with("a:1", "FROM alpine:3.4\nRUN echo x");
        let mut fleet = Fleet::new(FleetConfig::hpc(8));
        // warm node 0 only
        let ids: Vec<LayerId> = sharded.registry().layers.ids().cloned().collect();
        for id in &ids {
            let l = sharded.registry().layers.get(id).unwrap().clone();
            fleet.caches_mut()[0].admit(l);
        }
        let report = fleet.deploy(&mut sharded, "a:1").unwrap();
        assert_eq!(report.wan_bytes, 0, "existing holder seeds the cluster");
        assert_eq!(report.intra_bytes, bytes * 7);
    }

    #[test]
    fn fan_out_wave_timing_doubles_holders() {
        // 4 nodes, arity 1, single layer: seeder at t_seed, then waves
        // serve 1, then 2 nodes — two hops after the seed
        let (mut sharded, _, _) = registry_with("one:1", "FROM alpine:3.4");
        let mut cfg = FleetConfig::hpc(4);
        cfg.fan_out = FanOut::Peer { arity: 1 };
        cfg.per_layer_check = Duration::ZERO;
        let layers = sharded.registry().image("one:1").unwrap().layers.len();
        assert_eq!(layers, 1, "alpine base is a single layer");
        let bytes = sharded
            .registry()
            .layers
            .ids()
            .map(|id| sharded.registry().layers.get(id).unwrap().bytes)
            .sum::<u64>();
        let mut fleet = Fleet::new(cfg);
        let report = fleet.deploy(&mut sharded, "one:1").unwrap();
        let seed = PathCost::registry_wan().transfer(bytes);
        let hop = Fabric::aries().p2p(bytes, false);
        assert_eq!(report.makespan, seed + hop + hop);
    }

    #[test]
    fn report_renders_key_numbers() {
        let (mut sharded, _, _) = registry_with("a:1", "FROM ubuntu:16.04\nRUN echo x");
        let mut fleet = Fleet::new(FleetConfig::hpc(32));
        let r = fleet.deploy(&mut sharded, "a:1").unwrap();
        let text = r.render();
        assert!(text.contains("32 nodes"));
        assert!(text.contains("WAN"));
        assert!(text.contains("hit rate"));
        assert!(text.contains("ready events"));
        // the fault tail only appears when something went wrong
        assert!(!text.contains("retry(ies)"));
    }

    #[test]
    fn deploy_schedules_one_ready_event_per_node_per_layer() {
        let (mut sharded, _, layers) = registry_with("a:1", "FROM ubuntu:16.04\nRUN echo x");
        let n = 64;
        let mut fleet = Fleet::new(FleetConfig::hpc(n));
        let cold = fleet.deploy(&mut sharded, "a:1").unwrap();
        assert_eq!(cold.queue.pushes, (n * layers) as u64);
        assert_eq!(cold.queue.pops, cold.queue.pushes, "drained to empty");
        assert_eq!(cold.queue.depth, 0);
        // drained per layer: the high-water mark is one layer's worth
        // of in-flight completions, not the lifetime push count
        assert_eq!(cold.queue.depth_hwm, n);
        // a fully warm wave schedules nothing at all
        let warm = fleet.deploy(&mut sharded, "a:1").unwrap();
        assert_eq!(warm.queue.pushes, 0);
        assert_eq!(warm.queue.depth_hwm, 0);
    }

    #[test]
    fn bounded_caches_evict_and_refetch() {
        let (mut sharded, bytes, _) = registry_with("a:1", "FROM ubuntu:16.04\nRUN echo x");
        let mut cfg = FleetConfig::hpc(4);
        // caches too small for the whole image: something must go
        cfg.cache_capacity_bytes = bytes / 2;
        let mut fleet = Fleet::new(cfg);
        let cold = fleet.deploy(&mut sharded, "a:1").unwrap();
        assert!(cold.cache.evictions > 0, "capacity forces eviction");
        let warm = fleet.deploy(&mut sharded, "a:1").unwrap();
        assert!(
            warm.total_bytes() > 0,
            "evicted layers must be transferred again"
        );
    }

    // ---- fault-aware path ------------------------------------------

    #[test]
    fn retry_policy_backoff_caps_and_jitters() {
        let p = RetryPolicy::hpc();
        assert_eq!(p.backoff(1, None), Duration::from_millis(50));
        assert_eq!(p.backoff(2, None), Duration::from_millis(100));
        assert_eq!(p.backoff(20, None), Duration::from_secs_f64(5.0), "capped");
        assert_eq!(p.backoff(0, None), Duration::from_millis(50), "0 clamps");
        let mut rng = SimRng::new(7, "backoff");
        let jittered = p.backoff(3, Some(&mut rng));
        let base = p.backoff(3, None);
        let ratio = jittered.as_secs_f64() / base.as_secs_f64();
        assert!((0.8..=1.2).contains(&ratio), "{ratio}");
        // no-retry policy never waits
        assert_eq!(RetryPolicy::none().backoff(5, None), Duration::ZERO);
    }

    #[test]
    fn faultless_deploy_with_faults_matches_deploy_bit_for_bit() {
        let text = "FROM ubuntu:16.04\nRUN echo x";
        let (mut reg_a, _, _) = registry_with("a:1", text);
        let (mut reg_b, _, _) = registry_with("a:1", text);
        let mut fleet_a = Fleet::new(FleetConfig::hpc(48));
        let mut fleet_b = Fleet::new(FleetConfig::hpc(48));
        let base = fleet_a.deploy(&mut reg_a, "a:1").unwrap();
        let mut rng = SimRng::new(99, "chaos");
        let chaos = fleet_b
            .deploy_with_faults(
                &mut reg_b,
                "a:1",
                0..48,
                &FaultSchedule::none(),
                &RetryPolicy::hpc(),
                &mut rng,
            )
            .unwrap();
        assert_eq!(base, chaos, "empty schedule must be invisible");
        assert_eq!(base.render(), chaos.render());
        // and the rng stream was never consumed
        let mut fresh = SimRng::new(99, "chaos");
        assert_eq!(
            rng.uniform(0.0, 1.0).to_bits(),
            fresh.uniform(0.0, 1.0).to_bits()
        );
    }

    #[test]
    fn shard_outage_fails_over_to_surviving_shard() {
        let (mut sharded, bytes, _) = registry_with("a:1", "FROM ubuntu:16.04\nRUN echo x");
        let ids: Vec<LayerId> = sharded.registry().layers.ids().cloned().collect();
        let down = sharded.shard_of(&ids[0]);
        let hour = VirtualTime(3_600_000_000_000);
        let schedule = FaultSchedule::from_events(vec![
            (VirtualTime::ZERO, Fault::ShardOutage { shard: down }),
            (hour, Fault::ShardRecover { shard: down }),
        ]);
        sharded.apply_faults(&schedule);
        assert!(sharded.shard_down_at(down, VirtualTime::ZERO));
        assert_eq!(sharded.shard_next_up(down, VirtualTime::ZERO), Some(hour));
        let mut fleet = Fleet::new(FleetConfig::hpc(16));
        let mut rng = SimRng::new(1, "failover");
        let report = fleet
            .deploy_with_faults(
                &mut sharded,
                "a:1",
                0..16,
                &schedule,
                &RetryPolicy::hpc(),
                &mut rng,
            )
            .unwrap();
        assert!(report.failovers >= 1, "owner shard down => failover");
        assert_eq!(report.permanently_failed, 0);
        assert_eq!(report.wan_bytes, bytes, "failover still seeds each layer once");
        assert_eq!(report.retried_bytes, 0);
        assert_eq!(report.containers_started, 16);
        assert_eq!(report.fault.failovers, report.failovers);
    }

    #[test]
    fn drop_window_forces_retry_and_bytes_stay_conserved() {
        let (mut sharded, _, _) = registry_with("a:1", "FROM ubuntu:16.04\nRUN echo x");
        // every WAN transfer started before 200 ms is lost
        let schedule = FaultSchedule::from_events(vec![(
            VirtualTime::ZERO,
            Fault::TransferDrop {
                until: VirtualTime(200_000_000),
            },
        )]);
        let n = 8;
        let mut fleet = Fleet::new(FleetConfig::hpc(n));
        let mut rng = SimRng::new(3, "drops");
        let report = fleet
            .deploy_with_faults(
                &mut sharded,
                "a:1",
                0..n,
                &schedule,
                &RetryPolicy::hpc(),
                &mut rng,
            )
            .unwrap();
        assert!(report.retries >= 1, "transfers inside the window are lost");
        assert!(report.retried_bytes > 0);
        assert_eq!(report.permanently_failed, 0, "backoff escapes the window");
        // conservation: everything moved is either admitted into a
        // cache or accounted as wasted
        assert_eq!(
            report.total_bytes(),
            report.cache.bytes_inserted + report.retried_bytes
        );
        assert_eq!(report.delivered_bytes(), report.cache.bytes_inserted);
        let text = report.render();
        assert!(text.contains("retry(ies)"));
        // a warm re-deploy after the chaos is still free
        let warm = fleet.deploy(&mut sharded, "a:1").unwrap();
        assert_eq!(warm.total_bytes(), 0);
    }

    #[test]
    fn crashed_receiver_is_reserved_after_rejoin() {
        // 4 nodes, arity 1, single-layer image: node 1 is the seeder's
        // first fan-out target but is down when the copy arrives
        let (mut sharded, bytes, _) = registry_with("one:1", "FROM alpine:3.4");
        let mut cfg = FleetConfig::hpc(4);
        cfg.fan_out = FanOut::Peer { arity: 1 };
        cfg.per_layer_check = Duration::ZERO;
        let seed_t = PathCost::registry_wan().transfer(bytes);
        let hop = Fabric::aries().p2p(bytes, false);
        let rejoin = VirtualTime::ZERO + seed_t + hop + hop + hop;
        let schedule = FaultSchedule::from_events(vec![
            (VirtualTime::ZERO, Fault::NodeCrash { node: 1 }),
            (rejoin, Fault::NodeRejoin { node: 1 }),
        ]);
        let mut fleet = Fleet::new(cfg);
        let mut rng = SimRng::new(5, "rejoin");
        let report = fleet
            .deploy_with_faults(
                &mut sharded,
                "one:1",
                0..4,
                &schedule,
                &RetryPolicy::hpc(),
                &mut rng,
            )
            .unwrap();
        assert_eq!(report.permanently_failed, 0);
        assert_eq!(report.retried_bytes, bytes, "one wasted fan-out copy");
        assert!(report.retries >= 1);
        assert_eq!(report.containers_started, 4);
        assert_eq!(
            report.total_bytes(),
            report.cache.bytes_inserted + report.retried_bytes
        );
        for cache in fleet.caches() {
            assert_eq!(cache.len(), 1, "every node ends with the layer");
        }
    }

    #[test]
    fn never_rejoining_node_fails_permanently_without_hanging() {
        let (mut sharded, _, _) = registry_with("a:1", "FROM alpine:3.4\nRUN echo x");
        let schedule = FaultSchedule::from_events(vec![(
            VirtualTime::ZERO,
            Fault::NodeCrash { node: 2 },
        )]);
        let n = 4;
        let mut fleet = Fleet::new(FleetConfig::hpc(n));
        let mut rng = SimRng::new(6, "dead-node");
        let report = fleet
            .deploy_with_faults(
                &mut sharded,
                "a:1",
                0..n,
                &schedule,
                &RetryPolicy::hpc(),
                &mut rng,
            )
            .unwrap();
        assert_eq!(report.permanently_failed, 1);
        assert_eq!(report.containers_started, 3);
        assert!(fleet.failed_nodes()[2]);
        // a later wave remembers the corpse instead of re-counting it
        let again = fleet
            .deploy_with_faults(
                &mut sharded,
                "a:1",
                0..n,
                &schedule,
                &RetryPolicy::hpc(),
                &mut rng,
            )
            .unwrap();
        assert_eq!(again.permanently_failed, 0);
        assert_eq!(again.containers_started, 3);
    }

    #[test]
    fn endless_drop_window_terminates_with_permanent_failures() {
        let (mut sharded, bytes, layers) = registry_with("one:1", "FROM alpine:3.4");
        assert_eq!(layers, 1);
        // every WAN transfer for the next hour is lost; hpc backoff
        // sums to ~4 s, so all attempts burn out inside the window
        let schedule = FaultSchedule::from_events(vec![(
            VirtualTime::ZERO,
            Fault::TransferDrop {
                until: VirtualTime(3_600_000_000_000),
            },
        )]);
        let n = 4;
        let mut fleet = Fleet::new(FleetConfig::hpc(n));
        let mut rng = SimRng::new(8, "endless");
        let report = fleet
            .deploy_with_faults(
                &mut sharded,
                "one:1",
                0..n,
                &schedule,
                &RetryPolicy::hpc(),
                &mut rng,
            )
            .unwrap();
        let attempts = RetryPolicy::hpc().max_attempts as u64;
        assert_eq!(report.permanently_failed, n, "nobody can be seeded");
        assert_eq!(report.containers_started, 0);
        assert_eq!(report.wan_transfers as u64, attempts);
        assert_eq!(report.retried_bytes, bytes * attempts);
        assert_eq!(report.cache.bytes_inserted, 0);
        assert_eq!(
            report.total_bytes(),
            report.cache.bytes_inserted + report.retried_bytes
        );
    }

    #[test]
    fn scoped_deploy_targets_a_ring_and_later_rings_reuse_it() {
        let (mut sharded, bytes, _) = registry_with("one:1", "FROM alpine:3.4");
        let n = 8;
        let mut fleet = Fleet::new(FleetConfig::hpc(n));
        let mut rng = SimRng::new(9, "rings");
        let none = FaultSchedule::none();
        let canary = fleet
            .deploy_with_faults(&mut sharded, "one:1", 0..2, &none, &RetryPolicy::none(), &mut rng)
            .unwrap();
        assert_eq!(canary.nodes, 2);
        assert_eq!(canary.wan_bytes, bytes, "ring seeds over the WAN");
        assert_eq!(canary.intra_bytes, bytes, "one fan-out copy in the ring");
        assert_eq!(canary.containers_started, 2);
        let rest = fleet
            .deploy_with_faults(&mut sharded, "one:1", 2..n, &none, &RetryPolicy::none(), &mut rng)
            .unwrap();
        assert_eq!(rest.nodes, 6);
        assert_eq!(rest.wan_bytes, 0, "canary ring already holds the layer");
        assert_eq!(rest.intra_bytes, bytes * 6, "peers serve the fleet ring");
        assert_eq!(rest.containers_started, 6);
    }

    #[test]
    fn evict_storm_sheds_cache_and_forces_refetch() {
        let (mut sharded, bytes, _) = registry_with("a:1", "FROM ubuntu:16.04\nRUN echo x");
        let n = 4;
        let mut fleet = Fleet::new(FleetConfig::hpc(n));
        fleet.deploy(&mut sharded, "a:1").unwrap();
        // a storm strikes node 0 between the waves, wiping its cache
        let schedule = FaultSchedule::from_events(vec![(
            fleet.now(),
            Fault::CacheEvictStorm {
                node: 0,
                bytes: u64::MAX,
            },
        )]);
        let mut rng = SimRng::new(11, "storm");
        let report = fleet
            .deploy_with_faults(
                &mut sharded,
                "a:1",
                0..n,
                &schedule,
                &RetryPolicy::hpc(),
                &mut rng,
            )
            .unwrap();
        assert!(report.cache.evictions > 0, "storm shed the resident layers");
        assert_eq!(report.wan_bytes, 0, "peers re-serve the struck node");
        assert_eq!(report.intra_bytes, bytes, "refetch rides the fabric");
        // the storm fires once: a third wave is fully warm again
        let warm = fleet
            .deploy_with_faults(
                &mut sharded,
                "a:1",
                0..n,
                &schedule,
                &RetryPolicy::hpc(),
                &mut rng,
            )
            .unwrap();
        assert_eq!(warm.total_bytes(), 0);
        assert_eq!(warm.cache.evictions, 0);
    }
}
