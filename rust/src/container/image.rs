//! Images and layers.
//!
//! A [`Layer`] is an immutable filesystem delta produced by one build
//! directive; its [`LayerId`] is the sha256 of (parent layer id, the
//! directive text, the file manifest), so identical build steps on
//! identical parents hash identically — the property that makes layer
//! caching and registry dedup sound.  An [`Image`] is an ordered stack
//! of layer ids plus runtime configuration (env, entrypoint, arch
//! flags), itself content-addressed.

use sha2::{Digest, Sha256};

/// Content hash of a layer (hex sha256).
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct LayerId(
    /// Hex sha256 of the layer's build inputs.
    pub String,
);

/// Content hash of an image config (hex sha256).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ImageId(
    /// Hex sha256 of the image config.
    pub String,
);

impl std::fmt::Display for LayerId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", &self.0[..12.min(self.0.len())])
    }
}

impl std::fmt::Display for ImageId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", &self.0[..12.min(self.0.len())])
    }
}

/// One file recorded in a layer's manifest.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FileEntry {
    /// Absolute path inside the image.
    pub path: String,
    /// File size in bytes.
    pub bytes: u64,
}

/// An immutable filesystem delta.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Layer {
    /// Content hash (commits to parent, directive, and manifest).
    pub id: LayerId,
    /// The build directive that produced this layer (provenance).
    pub directive: String,
    /// Files added/changed by this layer.
    pub files: Vec<FileEntry>,
    /// Compressed transfer size (what push/pull move).
    pub bytes: u64,
}

impl Layer {
    /// Derive a layer from its parent, directive, and file manifest.
    /// The id commits to all three.
    pub fn derive(parent: Option<&LayerId>, directive: &str, files: Vec<FileEntry>) -> Self {
        let mut h = Sha256::new();
        h.update(parent.map(|p| p.0.as_str()).unwrap_or("scratch").as_bytes());
        h.update([0u8]);
        h.update(directive.as_bytes());
        for f in &files {
            h.update([0u8]);
            h.update(f.path.as_bytes());
            h.update(f.bytes.to_le_bytes());
        }
        let bytes = files.iter().map(|f| f.bytes).sum();
        Layer {
            id: LayerId(hex(&h.finalize())),
            directive: directive.to_string(),
            files,
            bytes,
        }
    }

    /// Number of files this layer adds or changes.
    pub fn file_count(&self) -> usize {
        self.files.len()
    }

    /// The layer as a transferable blob: id, provenance, and
    /// compressed size, but no file manifest.  This is what node
    /// caches and registries move around — the manifest stays with the
    /// catalogue copy, exactly as a compressed blob on a real node
    /// would.
    pub fn blob(&self) -> Layer {
        Layer {
            id: self.id.clone(),
            directive: self.directive.clone(),
            files: Vec::new(),
            bytes: self.bytes,
        }
    }
}

/// An immutable image: layer stack + runtime config.
#[derive(Debug, Clone, PartialEq)]
pub struct Image {
    /// Content hash of the image config.
    pub id: ImageId,
    /// `repository:tag`, e.g. `quay.io/fenicsproject/stable:2016.1.0r1`.
    pub reference: String,
    /// Layer stack, base first.
    pub layers: Vec<LayerId>,
    /// Environment variables (`ENV` directives).
    pub env: Vec<(String, String)>,
    /// Entrypoint command, if set.
    pub entrypoint: Option<String>,
    /// Image labels (`LABEL` directives).
    pub labels: Vec<(String, String)>,
    /// Whether the image was built with host-architecture optimisation
    /// (`ARCH_OPT` directive): controls the Fig 5a AVX penalty.
    pub arch_optimized: bool,
}

impl Image {
    /// Content-address an image from its parts.
    pub fn seal(
        reference: &str,
        layers: Vec<LayerId>,
        env: Vec<(String, String)>,
        entrypoint: Option<String>,
        labels: Vec<(String, String)>,
        arch_optimized: bool,
    ) -> Self {
        let mut h = Sha256::new();
        for l in &layers {
            h.update(l.0.as_bytes());
            h.update([0u8]);
        }
        for (k, v) in &env {
            h.update(k.as_bytes());
            h.update([b'=']);
            h.update(v.as_bytes());
        }
        if let Some(e) = &entrypoint {
            h.update(e.as_bytes());
        }
        h.update([arch_optimized as u8]);
        Image {
            id: ImageId(hex(&h.finalize())),
            reference: reference.to_string(),
            layers,
            env,
            entrypoint,
            labels,
            arch_optimized,
        }
    }

    /// Total compressed size given the layer store (bytes).
    pub fn size_bytes(&self, store: &super::LayerStore) -> u64 {
        self.layers
            .iter()
            .filter_map(|id| store.get(id))
            .map(|l| l.bytes)
            .sum()
    }

    /// Total number of files across layers (what an importer would see).
    pub fn file_count(&self, store: &super::LayerStore) -> usize {
        self.layers
            .iter()
            .filter_map(|id| store.get(id))
            .map(|l| l.file_count())
            .sum()
    }
}

pub(crate) fn hex(bytes: &[u8]) -> String {
    bytes.iter().map(|b| format!("{b:02x}")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn files(n: usize, sz: u64) -> Vec<FileEntry> {
        (0..n)
            .map(|i| FileEntry {
                path: format!("/usr/lib/f{i}.so"),
                bytes: sz,
            })
            .collect()
    }

    #[test]
    fn layer_id_is_content_addressed() {
        let a = Layer::derive(None, "RUN apt-get install scipy", files(3, 10));
        let b = Layer::derive(None, "RUN apt-get install scipy", files(3, 10));
        assert_eq!(a.id, b.id);
        let c = Layer::derive(None, "RUN apt-get install numpy", files(3, 10));
        assert_ne!(a.id, c.id);
    }

    #[test]
    fn layer_id_commits_to_parent() {
        let p1 = Layer::derive(None, "FROM ubuntu:16.04", files(1, 1));
        let p2 = Layer::derive(None, "FROM alpine:3.4", files(1, 1));
        let a = Layer::derive(Some(&p1.id), "RUN x", files(2, 5));
        let b = Layer::derive(Some(&p2.id), "RUN x", files(2, 5));
        assert_ne!(a.id, b.id);
    }

    #[test]
    fn layer_size_is_manifest_sum() {
        let l = Layer::derive(None, "RUN y", files(4, 100));
        assert_eq!(l.bytes, 400);
        assert_eq!(l.file_count(), 4);
    }

    #[test]
    fn image_id_commits_to_layers_and_config() {
        let l = Layer::derive(None, "FROM ubuntu", files(1, 1));
        let base = |arch| {
            Image::seal(
                "t:1",
                vec![l.id.clone()],
                vec![("A".into(), "1".into())],
                None,
                vec![],
                arch,
            )
        };
        assert_eq!(base(false).id, base(false).id);
        assert_ne!(base(false).id, base(true).id);
    }

    #[test]
    fn display_truncates_hash() {
        let l = Layer::derive(None, "RUN z", vec![]);
        assert_eq!(format!("{}", l.id).len(), 12);
    }
}
