//! Registry front-door protocol tier: resumable transfer sessions.
//!
//! The [`ShardedRegistry`](super::distribute::ShardedRegistry) models
//! shards as FIFO pipes; a production registry serves *sessions* — the
//! OCI distribution API the way Trow accounts it: per-upload UUIDs,
//! chunked blob transfers with byte-range progress, and
//! resume-after-disconnect that re-sends only the unacknowledged
//! ranges.  This module is that tier:
//!
//! ```text
//!   SessionRequest (pull/push, arrival time)
//!        │ open
//!        ▼
//!   ┌──────────────────────── FrontDoor ────────────────────────┐
//!   │ edge cache? ──hit──▶ serve locally (edge_hit_time)        │
//!   │     │miss                                                 │
//!   │     ▼            chunk by chunk                           │
//!   │ TransferSession ──────────────▶ ShardedRegistry frontends │
//!   │     ▲    │  ack: advance byte range    (FifoResource/WAN) │
//!   │     │    ▼                                                │
//!   │  RetryPolicy ◀─── FaultSchedule: TransferDrop/ShardOutage │
//!   │  (backoff, resume from last acked byte — not from zero)   │
//!   └───────────────────────────────────────────────────────────┘
//! ```
//!
//! Every concurrent session is multiplexed onto the shard frontends
//! through one calendar [`CellQueue`] (serial at `--domains 1`,
//! lookahead-partitioned by session index otherwise — see
//! [`crate::des::pdes`]), so submissions happen in
//! nondecreasing virtual time (the FIFO contract of
//! [`FifoResource`](crate::des::FifoResource)) and the whole run is a
//! deterministic function of `(requests, schedule, policy, seed)` —
//! byte-identical across machines and `--jobs` settings.
//!
//! Faults interrupt *sessions*, not whole transfers: a
//! [`TransferDrop`](crate::des::fault::Fault::TransferDrop) window
//! overlapping a chunk's flight loses that chunk only, and the
//! [`RetryPolicy`] resumes the session from its last acknowledged
//! byte.  Shard outages are absorbed by failover re-hashing (see
//! [`ShardedRegistry::submit_transfer_failover`]); when every shard is
//! dark the session parks until the earliest recovery.
//!
//! [`ShardedRegistry::submit_transfer_failover`]:
//! super::distribute::ShardedRegistry::submit_transfer_failover

use std::fmt;

use crate::des::{
    CellQueue, Duration, FaultSchedule, FaultStats, LatencyHistogram, QueueStats, SimRng,
    VirtualTime,
};
use crate::net::wan_lookahead;
use crate::util::rng::fnv1a;

use super::cache::LayerCache;
use super::distribute::{RetryPolicy, ShardAttempt, ShardedRegistry};
use super::image::{Layer, LayerId};

/// Default transfer chunk: 32 MB, the OCI chunked-upload sweet spot
/// against the 120 ms registry WAN RTT (per-chunk RTT overhead stays
/// near 10 % while a disconnect loses at most one chunk of progress).
pub const DEFAULT_CHUNK_BYTES: u64 = 32_000_000;

/// Per-session identifier, rendered UUID-style the way Trow names
/// blob uploads.  Allocated sequentially by the [`FrontDoor`], so ids
/// are deterministic; the UUID text is a pure hash of the counter.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SessionId(
    /// Sequential session counter within one front door.
    pub u64,
);

impl fmt::Display for SessionId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let h = fnv1a(self.0.to_le_bytes());
        let h2 = fnv1a(h.to_le_bytes().into_iter().chain([0x5e]));
        write!(
            f,
            "{:08x}-{:04x}-4{:03x}-{:04x}-{:012x}",
            (h >> 32) as u32,
            (h >> 16) as u16,
            h & 0xfff,
            0x8000 | (h2 as u16 & 0x3fff),
            (h2 >> 16) & 0xffff_ffff_ffff,
        )
    }
}

/// Which direction a session moves bytes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransferKind {
    /// Registry → client (a layer download).
    Pull,
    /// Client → registry (a chunked resumable blob upload; the layer
    /// enters the catalogue when the last chunk is acknowledged).
    Push,
}

/// One client request the front door will open as a session.
#[derive(Debug, Clone)]
pub struct SessionRequest {
    /// Arrival instant (sessions open in `(time, request-order)`).
    pub at: VirtualTime,
    /// Pull or push.
    pub kind: TransferKind,
    /// The layer the session moves.
    pub layer: LayerId,
    /// Upload payload (pushes only); the blob inserted into the
    /// registry store when the session completes.
    pub payload: Option<Layer>,
}

impl SessionRequest {
    /// A pull of `layer` arriving at `at`.
    pub fn pull(at: VirtualTime, layer: LayerId) -> Self {
        SessionRequest {
            at,
            kind: TransferKind::Pull,
            layer,
            payload: None,
        }
    }

    /// A push of `payload` arriving at `at`.
    pub fn push(at: VirtualTime, payload: Layer) -> Self {
        SessionRequest {
            at,
            kind: TransferKind::Push,
            layer: payload.id.clone(),
            payload: Some(payload),
        }
    }
}

/// One transfer session's byte-range progress and outcome.
///
/// `wire_bytes == acked_bytes + resent_bytes` holds per session by
/// construction: every chunk that crossed the WAN either advanced the
/// acknowledged range or was lost and re-sent from the last acked
/// byte — never from zero, and acked ranges are never sent twice.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TransferSession {
    /// Session identifier (UUID-style display).
    pub id: SessionId,
    /// Pull or push.
    pub kind: TransferKind,
    /// The layer moved.
    pub layer: LayerId,
    /// Full size of the transfer (0 until a pull resolves its layer).
    pub total_bytes: u64,
    /// Bytes acknowledged so far (resume point after a disconnect).
    pub acked_bytes: u64,
    /// Bytes that crossed the WAN, acknowledged or not.
    pub wire_bytes: u64,
    /// Bytes lost in flight and sent again.
    pub resent_bytes: u64,
    /// Chunks that completed transmission (acked or lost).
    pub chunks_sent: u64,
    /// Chunks acknowledged.
    pub chunks_acked: u64,
    /// Chunks lost to drop windows or timeouts.
    pub drops: u64,
    /// Re-attempts after a lost chunk or an all-shards-down park.
    pub retries: u64,
    /// Chunks served by a non-owner shard during an outage.
    pub failovers: u64,
    /// Instant the session opened.
    pub opened_at: VirtualTime,
    /// Instant the session delivered or was abandoned.
    pub done_at: VirtualTime,
    /// Whether every byte was delivered (or served from the edge
    /// cache); `false` means the retry budget ran out.
    pub delivered: bool,
    /// Whether the edge cache served the whole session.
    pub cache_hit: bool,
    /// Attempts spent on the chunk currently in flight (resets on each
    /// ack; bounds are [`RetryPolicy::max_attempts`]).
    attempt: u32,
}

impl TransferSession {
    /// Open-to-done span (abandon time for failed sessions).
    pub fn latency(&self) -> Duration {
        self.done_at.since(self.opened_at)
    }

    /// Attempts spent on the chunk in flight when the session ended
    /// (0 for a clean delivery — every ack resets the counter).
    pub fn attempts(&self) -> u32 {
        self.attempt
    }

    /// Fraction of the requested payload that reached the client, in
    /// `[0, 1]`.  Edge-cache hits serve every byte without touching
    /// the WAN, so they score 1.0 despite `acked_bytes == 0`; a
    /// session abandoned mid-transfer scores the fraction it acked
    /// before the retry budget ran out.
    pub fn availability(&self) -> f64 {
        if self.cache_hit {
            1.0
        } else if self.total_bytes == 0 {
            if self.delivered { 1.0 } else { 0.0 }
        } else {
            self.acked_bytes as f64 / self.total_bytes as f64
        }
    }
}

/// Front-door event: everything a run schedules through its calendar.
#[derive(Debug, Clone, Copy)]
enum Ev {
    /// Session `i` arrives and opens.
    Open(usize),
    /// A chunk of session `s` finished crossing the WAN (it was
    /// submitted at `start`); acknowledge or declare it lost.
    Sent {
        /// Session index.
        s: usize,
        /// Submission instant (the in-flight exposure is `[start, now)`).
        start: VirtualTime,
        /// Chunk size.
        bytes: u64,
    },
    /// Session `i` retries its current chunk after backoff.
    Retry(usize),
}

/// Aggregate outcome of one [`FrontDoor::run`].
///
/// The conservation invariant extends session-wise to the whole run:
/// `wire_bytes == payload_bytes + resent_bytes`, and a delivered
/// session contributed exactly its `total_bytes` to either
/// `payload_bytes` (WAN path) or `hit_bytes` (edge-cache path).
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct FrontDoorReport {
    /// Sessions opened.
    pub sessions: u64,
    /// Sessions that delivered every byte.
    pub delivered: u64,
    /// Sessions abandoned after the retry budget ran out.
    pub failed: u64,
    /// Sessions served whole from the edge cache.
    pub cache_hits: u64,
    /// WAN bytes acknowledged across all sessions.
    pub payload_bytes: u64,
    /// Bytes served from the edge cache instead of the WAN.
    pub hit_bytes: u64,
    /// Bytes that crossed the WAN, acknowledged or not.
    pub wire_bytes: u64,
    /// Bytes lost in flight and sent again.
    pub resent_bytes: u64,
    /// Chunks that completed transmission.
    pub chunks: u64,
    /// Injected faults and the sessions' reaction counters.
    pub fault: FaultStats,
    /// Calendar counters of the session event loop.
    pub queue: QueueStats,
    /// Delivered-session latency percentiles (deterministic log-binned
    /// estimator — see [`LatencyHistogram`]).
    pub latency: LatencyHistogram,
    /// Per-session availability percentiles: every session (delivered
    /// or abandoned) records [`TransferSession::availability`] scaled
    /// to nanoseconds (1.0 → 1 s), so `quantile(0.01)` reads the
    /// worst-percentile fraction of payload clients actually received
    /// under faults.
    pub availability: LatencyHistogram,
}

impl FrontDoorReport {
    /// Multi-line summary for traces and bench output.
    pub fn render(&self) -> String {
        let mb = |b: u64| b as f64 / 1e6;
        format!(
            "sessions {}: {} delivered ({} edge hit(s)), {} failed; \
             {:.1} MB payload + {:.1} MB resent = {:.1} MB wire in {} chunk(s)\n  \
             {}\n  {}\n  queue: {}",
            self.sessions,
            self.delivered,
            self.cache_hits,
            self.failed,
            mb(self.payload_bytes),
            mb(self.resent_bytes),
            mb(self.wire_bytes),
            self.chunks,
            self.latency.render(),
            self.fault.render(),
            self.queue.render(),
        )
    }
}

/// The registry front door: opens, multiplexes, interrupts, and
/// resumes concurrent transfer sessions over a [`ShardedRegistry`].
#[derive(Debug)]
pub struct FrontDoor {
    registry: ShardedRegistry,
    schedule: FaultSchedule,
    policy: RetryPolicy,
    chunk_bytes: u64,
    edge_cache: Option<LayerCache>,
    edge_hit_time: Duration,
    next_session: u64,
    domains: usize,
}

impl FrontDoor {
    /// A front door over `registry` with [`DEFAULT_CHUNK_BYTES`]
    /// chunks, no faults, no retries ([`RetryPolicy::none`] — the rng
    /// is never consulted), and no edge cache.
    pub fn new(registry: ShardedRegistry) -> Self {
        FrontDoor {
            registry,
            schedule: FaultSchedule::none(),
            policy: RetryPolicy::none(),
            chunk_bytes: DEFAULT_CHUNK_BYTES,
            edge_cache: None,
            edge_hit_time: Duration::from_millis(2),
            next_session: 0,
            domains: 1,
        }
    }

    /// Partition the session event loop into `domains` lookahead
    /// domains (see [`crate::des::pdes`]): sessions are routed by
    /// index under the WAN lookahead bound
    /// ([`crate::net::wan_lookahead`]).  Reports are byte-identical
    /// for any value — this is a pure parallelism knob (`--domains`);
    /// 1 (the default) keeps the serial reference queue.
    pub fn with_domains(mut self, domains: usize) -> Self {
        self.domains = domains.max(1);
        self
    }

    /// Override the transfer chunk size (must be ≥ 1).
    pub fn with_chunk_bytes(mut self, bytes: u64) -> Self {
        assert!(bytes >= 1, "chunks must move at least one byte");
        self.chunk_bytes = bytes;
        self
    }

    /// Override the retry policy sessions resume under.
    pub fn with_policy(mut self, policy: RetryPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Add an edge cache of `capacity_bytes`: pulls of resident layers
    /// are served locally in `edge_hit_time` instead of crossing the
    /// WAN, and delivered pulls are admitted for later sessions.
    pub fn with_edge_cache(mut self, capacity_bytes: u64) -> Self {
        self.edge_cache = Some(LayerCache::new(capacity_bytes));
        self
    }

    /// Install a fault schedule: its shard outage windows go to the
    /// [`ShardedRegistry`] (failover re-hashing) and its drop windows
    /// interrupt chunks in flight here.
    pub fn apply_faults(&mut self, schedule: FaultSchedule) {
        self.registry.apply_faults(&schedule);
        self.schedule = schedule;
    }

    /// The fronted registry.
    pub fn registry(&self) -> &ShardedRegistry {
        &self.registry
    }

    /// Mutable registry access (catalogue setup).
    pub fn registry_mut(&mut self) -> &mut ShardedRegistry {
        &mut self.registry
    }

    /// The edge cache, when one is configured.
    pub fn edge_cache(&self) -> Option<&LayerCache> {
        self.edge_cache.as_ref()
    }

    /// Current transfer chunk size.
    pub fn chunk_bytes(&self) -> u64 {
        self.chunk_bytes
    }

    /// Run every request to completion (delivery or abandonment) and
    /// return the per-session outcomes plus the aggregate report.
    ///
    /// The event loop is single-threaded and calendar-ordered, so the
    /// result is a deterministic function of the inputs; `rng` is
    /// consulted **only** for retry-backoff jitter (pass `None`, or a
    /// policy with zero jitter, and it is never touched — the
    /// fault-free bit-identity property the tests pin).
    ///
    /// A pull whose layer is unknown when the session opens is
    /// abandoned on the spot (counted in
    /// [`failed`](FrontDoorReport::failed)); a push inserts its
    /// payload into the catalogue when the last chunk is acknowledged,
    /// so later-opening pulls of that layer succeed within the same
    /// run.
    pub fn run(
        &mut self,
        requests: Vec<SessionRequest>,
        mut rng: Option<&mut SimRng>,
    ) -> (Vec<TransferSession>, FrontDoorReport) {
        let n = requests.len();
        let mut sessions: Vec<TransferSession> = Vec::with_capacity(n);
        let mut payloads: Vec<Option<Layer>> = Vec::with_capacity(n);
        let mut q: CellQueue<Ev> = CellQueue::new(self.domains, wan_lookahead(), n.max(1));
        let mut opens = Vec::with_capacity(n);
        for (i, req) in requests.into_iter().enumerate() {
            sessions.push(TransferSession {
                id: SessionId(self.next_session),
                kind: req.kind,
                layer: req.layer,
                total_bytes: match req.kind {
                    TransferKind::Push => req.payload.as_ref().map_or(0, |l| l.bytes),
                    TransferKind::Pull => 0, // resolved when the session opens
                },
                acked_bytes: 0,
                wire_bytes: 0,
                resent_bytes: 0,
                chunks_sent: 0,
                chunks_acked: 0,
                drops: 0,
                retries: 0,
                failovers: 0,
                opened_at: req.at,
                done_at: req.at,
                delivered: false,
                cache_hit: false,
                attempt: 0,
            });
            self.next_session += 1;
            payloads.push(req.payload);
            opens.push((i, req.at, Ev::Open(i)));
        }
        q.push_batch(opens);

        while let Some((now, ev)) = q.pop() {
            match ev {
                Ev::Open(i) => {
                    sessions[i].opened_at = now;
                    if sessions[i].kind == TransferKind::Pull {
                        let Some(found) = self.registry.registry().layers.get(&sessions[i].layer)
                        else {
                            sessions[i].done_at = now; // unknown layer: abandon
                            continue;
                        };
                        sessions[i].total_bytes = found.bytes;
                        let hit = self
                            .edge_cache
                            .as_mut()
                            .is_some_and(|c| c.lookup(&sessions[i].layer).is_some());
                        if hit {
                            sessions[i].cache_hit = true;
                            sessions[i].delivered = true;
                            sessions[i].done_at = now + self.edge_hit_time;
                            continue;
                        }
                        if self.edge_cache.is_some() {
                            payloads[i] = self
                                .registry
                                .registry()
                                .layers
                                .get(&sessions[i].layer)
                                .cloned();
                        }
                    }
                    if sessions[i].total_bytes == 0 {
                        self.complete(i, now, &mut sessions, &mut payloads);
                    } else if !self.send_chunk(i, now, &mut sessions, &mut q, &mut rng) {
                        sessions[i].done_at = now;
                    }
                }
                Ev::Sent { s: i, start, bytes } => {
                    sessions[i].wire_bytes += bytes;
                    sessions[i].chunks_sent += 1;
                    let timed_out = self
                        .policy
                        .timeout
                        .is_some_and(|limit| now.since(start) > limit);
                    if timed_out || self.schedule.drop_overlapping(start, now).is_some() {
                        // the chunk is lost; the acked range is not —
                        // the retry resumes from the last acked byte
                        sessions[i].resent_bytes += bytes;
                        sessions[i].drops += 1;
                        if sessions[i].attempt >= self.policy.max_attempts {
                            sessions[i].done_at = now; // budget exhausted
                        } else {
                            let wait =
                                self.policy.backoff(sessions[i].attempt, rng.as_deref_mut());
                            sessions[i].retries += 1;
                            q.push(i, now + wait, Ev::Retry(i));
                        }
                    } else {
                        sessions[i].acked_bytes += bytes;
                        sessions[i].chunks_acked += 1;
                        sessions[i].attempt = 0;
                        if sessions[i].acked_bytes >= sessions[i].total_bytes {
                            self.complete(i, now, &mut sessions, &mut payloads);
                        } else if !self.send_chunk(i, now, &mut sessions, &mut q, &mut rng) {
                            sessions[i].done_at = now;
                        }
                    }
                }
                Ev::Retry(i) => {
                    if !self.send_chunk(i, now, &mut sessions, &mut q, &mut rng) {
                        sessions[i].done_at = now;
                    }
                }
            }
        }

        let mut report = FrontDoorReport {
            queue: q.stats(),
            ..FrontDoorReport::default()
        };
        let mut end = VirtualTime::ZERO;
        for s in &sessions {
            report.sessions += 1;
            end = end.max(s.done_at);
            report
                .availability
                .record(Duration::from_nanos((s.availability() * 1e9).round() as u64));
            if s.delivered {
                report.delivered += 1;
                report.latency.record(s.latency());
                if s.cache_hit {
                    report.cache_hits += 1;
                    report.hit_bytes += s.total_bytes;
                }
            } else {
                report.failed += 1;
            }
            report.payload_bytes += s.acked_bytes;
            report.wire_bytes += s.wire_bytes;
            report.resent_bytes += s.resent_bytes;
            report.chunks += s.chunks_sent;
        }
        report.fault = self.schedule.stats_over(VirtualTime::ZERO, end);
        for s in &sessions {
            report.fault.transfers_dropped += s.drops;
            report.fault.retries += s.retries;
            report.fault.failovers += s.failovers;
        }
        report.fault.permanent_failures += report.failed;
        (sessions, report)
    }

    /// Submit the next unacked chunk of session `i` at `now`.  Returns
    /// `false` when the session must be abandoned (retry budget
    /// exhausted, or no shard ever recovers).
    fn send_chunk(
        &mut self,
        i: usize,
        now: VirtualTime,
        sessions: &mut [TransferSession],
        q: &mut CellQueue<Ev>,
        rng: &mut Option<&mut SimRng>,
    ) -> bool {
        let s = &mut sessions[i];
        let chunk = (s.total_bytes - s.acked_bytes).min(self.chunk_bytes);
        s.attempt += 1;
        match self.registry.submit_transfer_failover(now, &s.layer, chunk) {
            ShardAttempt::Served { done, failover, .. } => {
                if failover {
                    s.failovers += 1;
                }
                q.push(i, done, Ev::Sent { s: i, start: now, bytes: chunk });
                true
            }
            ShardAttempt::AllDown { next_up } => {
                // nothing crossed the WAN; park until a shard recovers
                let Some(up) = next_up else { return false };
                if s.attempt >= self.policy.max_attempts {
                    return false;
                }
                let wait = self.policy.backoff(s.attempt, rng.as_deref_mut());
                s.retries += 1;
                q.push(i, up.max(now) + wait, Ev::Retry(i));
                true
            }
        }
    }

    /// Finalise a delivered session at `now`: pushes land their
    /// payload in the catalogue, pulls warm the edge cache.
    fn complete(
        &mut self,
        i: usize,
        now: VirtualTime,
        sessions: &mut [TransferSession],
        payloads: &mut [Option<Layer>],
    ) {
        let s = &mut sessions[i];
        s.delivered = true;
        s.done_at = now;
        match s.kind {
            TransferKind::Push => {
                if let Some(layer) = payloads[i].take() {
                    self.registry.registry_mut().layers.insert(layer);
                }
            }
            TransferKind::Pull => {
                if let (Some(cache), Some(layer)) = (self.edge_cache.as_mut(), payloads[i].take())
                {
                    cache.admit(layer);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::container::image::FileEntry;
    use crate::container::registry::Registry;
    use crate::des::fault::Fault;

    fn layer(tag: &str, bytes: u64) -> Layer {
        Layer::derive(
            None,
            tag,
            vec![FileEntry {
                path: format!("/{tag}"),
                bytes,
            }],
        )
    }

    fn front_with(layers: &[Layer], shards: usize) -> FrontDoor {
        let mut reg = Registry::new();
        for l in layers {
            reg.layers.insert(l.clone());
        }
        FrontDoor::new(ShardedRegistry::new(reg, shards))
    }

    fn sec(s: f64) -> VirtualTime {
        VirtualTime::ZERO + Duration::from_secs_f64(s)
    }

    #[test]
    fn session_id_displays_uuid_shaped() {
        let text = SessionId(7).to_string();
        assert_eq!(text.len(), 36);
        for at in [8, 13, 18, 23] {
            assert_eq!(&text[at..=at], "-", "{text}");
        }
        assert_eq!(&text[14..15], "4", "version nibble: {text}");
        assert_ne!(SessionId(8).to_string(), text);
        assert_eq!(SessionId(7).to_string(), text, "display is pure");
    }

    #[test]
    fn single_pull_round_trip() {
        let l = layer("base", 100_000_000);
        let total = l.bytes;
        let mut fd = front_with(&[l.clone()], 4).with_chunk_bytes(10_000_000);
        let (sessions, report) =
            fd.run(vec![SessionRequest::pull(sec(0.0), l.id.clone())], None);
        let s = &sessions[0];
        assert!(s.delivered && !s.cache_hit);
        assert_eq!(s.acked_bytes, total, "delivered == total");
        assert_eq!(s.wire_bytes, total);
        assert_eq!(s.resent_bytes, 0);
        assert_eq!(s.chunks_sent, total.div_ceil(10_000_000));
        assert_eq!(s.chunks_sent, s.chunks_acked);
        assert!(s.latency() > Duration::ZERO);
        assert_eq!(report.delivered, 1);
        assert_eq!(report.payload_bytes, total);
        assert_eq!(report.wire_bytes, report.payload_bytes + report.resent_bytes);
        assert_eq!(report.latency.count(), 1);
        assert!(report.render().contains("1 delivered"));
    }

    #[test]
    fn chunking_pays_per_chunk_rtt() {
        let l = layer("base", 64_000_000);
        let mut coarse = front_with(&[l.clone()], 1).with_chunk_bytes(64_000_000);
        let mut fine = front_with(&[l.clone()], 1).with_chunk_bytes(1_000_000);
        let (a, _) = coarse.run(vec![SessionRequest::pull(sec(0.0), l.id.clone())], None);
        let (b, _) = fine.run(vec![SessionRequest::pull(sec(0.0), l.id.clone())], None);
        assert!(
            b[0].latency() > a[0].latency(),
            "64 RTTs > 1 RTT: {} vs {}",
            b[0].latency(),
            a[0].latency()
        );
    }

    #[test]
    fn push_lands_layer_and_later_pull_sees_it() {
        let l = layer("pushed", 10_000_000);
        let id = l.id.clone();
        let mut fd = front_with(&[], 2);
        assert!(!fd.registry().registry().layers.contains(&id));
        let (sessions, report) = fd.run(
            vec![
                SessionRequest::push(sec(0.0), l),
                SessionRequest::pull(sec(10.0), id.clone()),
            ],
            None,
        );
        assert!(sessions[0].delivered, "push delivered");
        assert!(sessions[1].delivered, "pull opened after the push landed");
        assert_eq!(sessions[1].total_bytes, 10_000_000);
        assert_eq!(report.delivered, 2);
        assert!(fd.registry().registry().layers.contains(&id));
    }

    #[test]
    fn unknown_pull_is_abandoned() {
        let mut fd = front_with(&[], 2);
        let (sessions, report) = fd.run(
            vec![SessionRequest::pull(sec(0.0), LayerId("ghost".into()))],
            None,
        );
        assert!(!sessions[0].delivered);
        assert_eq!(sessions[0].wire_bytes, 0);
        assert_eq!(report.failed, 1);
        assert_eq!(report.fault.permanent_failures, 1);
    }

    #[test]
    fn drop_window_loses_one_chunk_and_resumes_from_acked_range() {
        let l = layer("big", 100_000_000);
        let total = l.bytes;
        let mut fd = front_with(&[l.clone()], 1)
            .with_chunk_bytes(10_000_000)
            .with_policy(RetryPolicy::hpc());
        // one drop window mid-transfer: ~10 chunks x ~450 ms each
        fd.apply_faults(FaultSchedule::from_events(vec![(
            sec(1.0),
            Fault::TransferDrop { until: sec(1.5) },
        )]));
        let (sessions, report) = fd.run(vec![SessionRequest::pull(sec(0.0), l.id)], None);
        let s = &sessions[0];
        assert!(s.delivered, "retry resumed the session");
        assert_eq!(s.acked_bytes, total, "delivered == total");
        assert!(s.drops >= 1 && s.retries >= 1, "{s:?}");
        assert!(s.resent_bytes >= 10_000_000, "the lost chunk was re-sent");
        assert!(
            s.resent_bytes < total,
            "resume re-sends only unacked ranges, not the whole blob"
        );
        assert_eq!(s.wire_bytes, s.acked_bytes + s.resent_bytes);
        assert_eq!(report.wire_bytes, report.payload_bytes + report.resent_bytes);
        assert!(report.fault.transfers_dropped >= 1);
    }

    #[test]
    fn permanent_outage_abandons_after_budget() {
        let l = layer("doomed", 50_000_000);
        let mut fd = front_with(&[l.clone()], 2).with_policy(RetryPolicy::hpc());
        // both shards go dark before the pull and never recover
        fd.apply_faults(FaultSchedule::from_events(vec![
            (sec(0.0), Fault::ShardOutage { shard: 0 }),
            (sec(0.0), Fault::ShardOutage { shard: 1 }),
        ]));
        let (sessions, report) = fd.run(vec![SessionRequest::pull(sec(1.0), l.id)], None);
        assert!(!sessions[0].delivered);
        assert_eq!(sessions[0].wire_bytes, 0, "nothing ever crossed the WAN");
        assert_eq!(report.failed, 1);
        assert_eq!(report.fault.permanent_failures, 1);
    }

    #[test]
    fn shard_outage_fails_over_mid_session() {
        let l = layer("failover", 60_000_000);
        let mut fd = front_with(&[l.clone()], 2)
            .with_chunk_bytes(10_000_000)
            .with_policy(RetryPolicy::hpc());
        let owner = fd.registry().shard_of(&l.id);
        fd.apply_faults(FaultSchedule::from_events(vec![
            (sec(0.0), Fault::ShardOutage { shard: owner }),
            (sec(60.0), Fault::ShardRecover { shard: owner }),
        ]));
        let (sessions, report) = fd.run(vec![SessionRequest::pull(sec(0.5), l.id)], None);
        let s = &sessions[0];
        assert!(s.delivered);
        assert!(s.failovers >= 1, "owner dark: chunks re-hashed, {s:?}");
        assert_eq!(s.resent_bytes, 0, "failover is not a loss");
        assert!(report.fault.failovers >= 1);
    }

    #[test]
    fn edge_cache_serves_repeat_pulls_locally() {
        let l = layer("hot", 30_000_000);
        let total = l.bytes;
        let mut fd = front_with(&[l.clone()], 2).with_edge_cache(u64::MAX);
        let (sessions, report) = fd.run(
            vec![
                SessionRequest::pull(sec(0.0), l.id.clone()),
                SessionRequest::pull(sec(100.0), l.id.clone()),
            ],
            None,
        );
        assert!(!sessions[0].cache_hit, "cold first pull");
        assert!(sessions[1].cache_hit, "warm second pull");
        assert!(sessions[1].latency() < sessions[0].latency());
        assert_eq!(sessions[1].wire_bytes, 0);
        assert_eq!(report.cache_hits, 1);
        assert_eq!(report.hit_bytes, total);
        assert_eq!(report.payload_bytes, total, "WAN paid once");
        let stats = fd.edge_cache().expect("configured").stats();
        assert_eq!(stats.hits, 1);
        assert_eq!(stats.misses, 1);
    }

    #[test]
    fn concurrent_sessions_interleave_on_shards() {
        let a = layer("a", 40_000_000);
        let b = layer("b", 40_000_000);
        let mut fd = front_with(&[a.clone(), b.clone()], 1).with_chunk_bytes(10_000_000);
        let (sessions, _) = fd.run(
            vec![
                SessionRequest::pull(sec(0.0), a.id.clone()),
                SessionRequest::pull(sec(0.0), b.id.clone()),
            ],
            None,
        );
        assert!(sessions.iter().all(|s| s.delivered));
        // one shard, interleaved chunks: both finish later than a solo
        // run, and neither monopolises the pipe
        let mut solo = front_with(&[a.clone()], 1).with_chunk_bytes(10_000_000);
        let (alone, _) = solo.run(vec![SessionRequest::pull(sec(0.0), a.id)], None);
        assert!(sessions[0].latency() > alone[0].latency());
        assert!(sessions[1].latency() > alone[0].latency());
    }

    #[test]
    fn run_is_deterministic_and_ids_are_sequential() {
        let l = layer("det", 25_000_000);
        let reqs = vec![
            SessionRequest::pull(sec(0.0), l.id.clone()),
            SessionRequest::pull(sec(0.1), l.id.clone()),
        ];
        let (s1, r1) = front_with(&[l.clone()], 2).run(reqs.clone(), None);
        let (s2, r2) = front_with(&[l.clone()], 2).run(reqs, None);
        assert_eq!(s1, s2);
        assert_eq!(r1, r2);
        assert_eq!(s1[0].id, SessionId(0));
        assert_eq!(s1[1].id, SessionId(1));
    }

    #[test]
    fn zero_byte_push_completes_instantly() {
        let mut l = layer("empty", 0);
        l.bytes = 0;
        let mut fd = front_with(&[], 1);
        let (sessions, report) = fd.run(vec![SessionRequest::push(sec(2.0), l)], None);
        assert!(sessions[0].delivered);
        assert_eq!(sessions[0].done_at, sec(2.0));
        assert_eq!(report.wire_bytes, 0);
    }
}
