//! The "Python import problem" (§4.2, Fig 4).
//!
//! `import dolfin` on every MPI rank walks a deep module graph; each
//! module costs a handful of filesystem metadata operations (locate the
//! `.py`, the `.pyc`, `__init__` chains) plus a small read.  On a
//! parallel filesystem those lookups contend at the metadata server; on
//! a loop-mounted container image they hit the node page cache (after
//! one bulk fetch).  [`ModuleGraph`] synthesises a FEniCS-scale import
//! set; [`replay`] runs it for every rank against any [`FileSystem`]
//! model and returns per-rank completion times.
//!
//! Scale reference: the paper reports >30 minutes at 1000 ranks on some
//! systems, citing [17] (ARCHER measured minutes at hundreds of ranks);
//! `fenics_stack()` sizes the graph to match FEniCS 2016 (~5k module
//! files across dolfin/ufl/ffc/instant/numpy/sympy/six...).

use crate::cluster::Allocation;
use crate::des::{Duration, VirtualTime};
use crate::fs::{FileSystem, FsOp};

/// One module to import.
#[derive(Debug, Clone)]
pub struct Module {
    /// Dotted module name.
    pub name: String,
    /// Metadata operations the interpreter issues to locate it
    /// (path-entry stats, `.py`/`.pyc` lookups).
    pub meta_ops: u32,
    /// Source bytes read (and byte-compiled on first import).
    pub bytes: u64,
}

/// A package's worth of modules.
#[derive(Debug, Clone)]
pub struct ModuleGraph {
    /// Modules in import order.
    pub modules: Vec<Module>,
}

impl ModuleGraph {
    /// The FEniCS Python stack, sized from the 2016-era packages.
    pub fn fenics_stack() -> Self {
        // (package, module files, mean source bytes)
        let packages: &[(&str, usize, u64)] = &[
            ("dolfin", 320, 9_000),
            ("ufl", 180, 11_000),
            ("ffc", 140, 10_000),
            ("fiat", 90, 12_000),
            ("instant", 40, 8_000),
            ("numpy", 420, 14_000),
            ("scipy", 600, 13_000),
            ("sympy", 900, 15_000),
            ("mpi4py", 30, 9_000),
            ("six+setuptools+pkg_resources", 160, 10_000),
            ("stdlib", 800, 7_000),
        ];
        let mut modules = Vec::new();
        for (pkg, count, mean) in packages {
            for i in 0..*count {
                modules.push(Module {
                    name: format!("{pkg}.m{i}"),
                    // sys.path has several entries; CPython stats each
                    meta_ops: 4,
                    bytes: *mean,
                });
            }
        }
        ModuleGraph { modules }
    }

    /// A small graph for tests.
    pub fn small(n: usize) -> Self {
        ModuleGraph {
            modules: (0..n)
                .map(|i| Module {
                    name: format!("m{i}"),
                    meta_ops: 3,
                    bytes: 4_000,
                })
                .collect(),
        }
    }

    /// Number of module files the import touches.
    pub fn total_files(&self) -> usize {
        self.modules.len()
    }

    /// Total metadata operations the import issues.
    pub fn total_meta_ops(&self) -> u64 {
        self.modules.iter().map(|m| m.meta_ops as u64).sum()
    }
}

/// Result of replaying the import phase.
#[derive(Debug, Clone)]
pub struct ImportReport {
    /// Per-rank completion instant.
    pub rank_done: Vec<VirtualTime>,
    /// Max across ranks minus start (the phase's wall time).
    pub wall: Duration,
}

/// Replay the import of `graph` on every rank of `alloc`, all starting
/// at `start`, against filesystem `fs`.  Each rank issues its modules'
/// metadata ops and reads sequentially (CPython imports are serial);
/// cross-rank contention emerges inside the filesystem model.
pub fn replay(
    graph: &ModuleGraph,
    alloc: &Allocation,
    fs: &mut dyn FileSystem,
    start: VirtualTime,
) -> ImportReport {
    let ranks = alloc.ranks();
    let mut clocks = vec![start; ranks];
    // interleave ranks module-by-module: closer to the real arrival
    // pattern at the MDS than letting rank 0 finish everything first
    for module in &graph.modules {
        for (rank, clock) in clocks.iter_mut().enumerate() {
            let node = alloc.node_of[rank];
            let mut t = *clock;
            // PERF: a module's metadata ops are sequential RPCs from one
            // rank; batching them as one queue entry of meta_ops x
            // service preserves per-rank totals and MDS utilisation
            // while cutting simulator work ~4x (EXPERIMENTS.md §Perf).
            t = fs.submit_meta_batch(t, node, module.meta_ops);
            t = fs.submit(t, node, FsOp::Read { bytes: module.bytes });
            // parse/compile cost (CPU, not FS): ~2 us per KB of source
            t += Duration::from_nanos(module.bytes * 2);
            *clock = t;
        }
    }
    let done = clocks.iter().copied().max().unwrap_or(start);
    ImportReport {
        rank_done: clocks,
        wall: done - start,
    }
}

/// Node-batched import replay: all symmetric ranks of a node issue each
/// module as one [`FileSystem::submit_batch`] burst (metadata, then the
/// read), so the replay runs in O(nodes × modules) instead of
/// O(ranks × modules) — the collapse that makes native Fig 4 tractable
/// at 1k–100k ranks (EXPERIMENTS.md §Perf).
///
/// Exactness follows the filesystem: on an [`ImageFs`](crate::fs::ImageFs)
/// every rank of a node completes page-cache operations at the identical
/// instant, so the batched replay is bit-identical to [`replay`]; on a
/// contended [`ParallelFs`](crate::fs::ParallelFs) the burst occupies the
/// same MDS handler time but samples load/noise once per burst and
/// completes together at its last member — a collapsed view that keeps
/// the contention curve (tested against the per-rank replay in
/// tests/batched_equivalence.rs).
pub fn replay_batched(
    graph: &ModuleGraph,
    alloc: &Allocation,
    fs: &mut dyn FileSystem,
    start: VirtualTime,
) -> ImportReport {
    let nodes = alloc.nodes_used;
    let mut count = vec![0u32; nodes];
    for &n in &alloc.node_of {
        count[n] += 1;
    }
    let mut node_clock = vec![start; nodes];
    // same module-major interleaving as `replay`: every node's burst for
    // module k arrives before any node's burst for module k+1
    for module in &graph.modules {
        for (node, clock) in node_clock.iter_mut().enumerate() {
            *clock = module_burst(fs, node, count[node], module, *clock);
        }
    }
    let rank_done: Vec<VirtualTime> = alloc.node_of.iter().map(|&n| node_clock[n]).collect();
    let done = rank_done.iter().copied().max().unwrap_or(start);
    ImportReport {
        rank_done,
        wall: done - start,
    }
}

/// One module's node-burst: the metadata batch, the source read, and
/// the parse/compile cost (~2 µs per KB of source; CPU, not FS), for
/// `count` symmetric ranks of `node` starting at `t`.  The single
/// definition of the import-storm step — [`replay_batched`] and the
/// mixed-fleet co-scheduling replay
/// ([`crate::workload::mixed`]) both charge exactly this, so the two
/// import models cannot drift apart.
pub fn module_burst(
    fs: &mut dyn FileSystem,
    node: usize,
    count: u32,
    module: &Module,
    t: VirtualTime,
) -> VirtualTime {
    let t = fs.submit_batch(t, node, count, FsOp::MetaBatch { ops: module.meta_ops });
    let t = fs.submit_batch(t, node, count, FsOp::Read { bytes: module.bytes });
    t + Duration::from_nanos(module.bytes * 2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{launch, MachineSpec};
    use crate::fs::{ImageFs, LocalFs, ParallelFs};

    #[test]
    fn fenics_stack_is_fenics_sized() {
        let g = ModuleGraph::fenics_stack();
        assert!(g.total_files() > 3_000, "got {}", g.total_files());
        assert!(g.total_files() < 10_000);
        assert!(g.total_meta_ops() > 10_000);
    }

    #[test]
    fn contention_grows_with_ranks_on_lustre() {
        let m = MachineSpec::edison();
        let g = ModuleGraph::small(200);
        let mut walls = Vec::new();
        for ranks in [24usize, 96] {
            let alloc = launch(&m, ranks).unwrap();
            let mut fs = ParallelFs::edison(1);
            let rep = replay(&g, &alloc, &mut fs, VirtualTime::ZERO);
            walls.push(rep.wall.as_secs_f64());
        }
        assert!(
            walls[1] > 2.0 * walls[0],
            "import should degrade with rank count: {walls:?}"
        );
    }

    #[test]
    fn image_mount_beats_lustre_by_a_lot() {
        let m = MachineSpec::edison();
        let alloc = launch(&m, 96).unwrap();
        let g = ModuleGraph::fenics_stack();

        let mut lustre = ParallelFs::edison(2);
        let native = replay(&g, &alloc, &mut lustre, VirtualTime::ZERO).wall;

        let mut image = ImageFs::new(1_200_000_000, ParallelFs::edison(3));
        let contained = replay(&g, &alloc, &mut image, VirtualTime::ZERO).wall;

        assert!(
            native.as_secs_f64() > 5.0 * contained.as_secs_f64(),
            "native {native} vs container {contained}"
        );
    }

    #[test]
    fn workstation_import_is_fast_either_way() {
        let m = MachineSpec::workstation();
        let alloc = launch(&m, 1).unwrap();
        let g = ModuleGraph::fenics_stack();
        let mut fs = LocalFs::default();
        let rep = replay(&g, &alloc, &mut fs, VirtualTime::ZERO);
        assert!(rep.wall.as_secs_f64() < 2.0, "got {}", rep.wall);
    }

    #[test]
    fn all_ranks_complete_and_are_recorded() {
        let m = MachineSpec::edison();
        let alloc = launch(&m, 48).unwrap();
        let g = ModuleGraph::small(10);
        let mut fs = ParallelFs::edison(4);
        let rep = replay(&g, &alloc, &mut fs, VirtualTime::ZERO);
        assert_eq!(rep.rank_done.len(), 48);
        let max = rep.rank_done.iter().copied().max().unwrap();
        assert_eq!(max - VirtualTime::ZERO, rep.wall);
    }

    #[test]
    fn batched_replay_is_exact_on_image_mounts() {
        // page-cache service completes every rank of a node at the same
        // instant, so node-batching loses nothing
        let m = MachineSpec::edison();
        let alloc = launch(&m, 96).unwrap();
        let g = ModuleGraph::small(50);
        let mut a = ImageFs::new(1_200_000_000, ParallelFs::edison(9));
        let mut b = ImageFs::new(1_200_000_000, ParallelFs::edison(9));
        let per_rank = replay(&g, &alloc, &mut a, VirtualTime::ZERO);
        let batched = replay_batched(&g, &alloc, &mut b, VirtualTime::ZERO);
        assert_eq!(per_rank.rank_done, batched.rank_done);
        assert_eq!(per_rank.wall, batched.wall);
    }

    #[test]
    fn batched_replay_keeps_lustre_contention_curve() {
        let m = MachineSpec::edison();
        let g = ModuleGraph::small(120);
        let wall = |ranks: usize| {
            let alloc = launch(&m, ranks).unwrap();
            let mut fs = ParallelFs::edison(1);
            replay_batched(&g, &alloc, &mut fs, VirtualTime::ZERO).wall.as_secs_f64()
        };
        let (w24, w96) = (wall(24), wall(96));
        assert!(w96 > 2.0 * w24, "contention must still grow: {w24} -> {w96}");
        // and agree with the per-rank replay within the burst-noise band
        let alloc = launch(&m, 96).unwrap();
        let mut fs = ParallelFs::edison(1);
        let per_rank = replay(&g, &alloc, &mut fs, VirtualTime::ZERO).wall.as_secs_f64();
        let ratio = w96 / per_rank;
        assert!((0.4..2.5).contains(&ratio), "batched/per-rank = {ratio:.3}");
    }

    #[test]
    fn replay_respects_start_time() {
        let m = MachineSpec::workstation();
        let alloc = launch(&m, 2).unwrap();
        let g = ModuleGraph::small(5);
        let mut fs = LocalFs::default();
        let start = VirtualTime::ZERO + Duration::from_millis(500);
        let rep = replay(&g, &alloc, &mut fs, start);
        assert!(rep.rank_done.iter().all(|&t| t > start));
    }
}
