//! Deterministic simulation randomness.
//!
//! Every stochastic element of the simulation (run-to-run jitter that
//! produces the paper's error bars, filesystem service-time noise) draws
//! from a `SimRng` seeded from the experiment seed + a stream label, so
//! results are reproducible and independent streams don't alias.

use crate::util::rng::{fnv1a, Xoshiro256};

/// Deterministic RNG stream for one simulation component.
#[derive(Debug, Clone)]
pub struct SimRng {
    rng: Xoshiro256,
    /// Spare Box–Muller normal (the transform yields two per draw;
    /// caching the sine branch halves the ln/sqrt cost in FS-noise-heavy
    /// simulations — EXPERIMENTS.md §Perf).
    spare_normal: Option<f64>,
}

impl SimRng {
    /// Derive a stream from an experiment seed and a component label.
    pub fn new(seed: u64, stream: &str) -> Self {
        // fold the label into the seed with FNV-1a so streams differ
        SimRng {
            rng: Xoshiro256::seed_from_u64(seed ^ fnv1a(stream.bytes())),
            spare_normal: None,
        }
    }

    /// Multiplicative jitter factor in `[1-eps, 1+eps]` (uniform).
    pub fn jitter(&mut self, eps: f64) -> f64 {
        1.0 + self.rng.range_f64(-eps, eps)
    }

    /// Heavy-tail factor >= 1 used for FS contention spikes:
    /// `1 + |N(0,1)| * sigma` via Box–Muller (both branches used).
    pub fn spike(&mut self, sigma: f64) -> f64 {
        let n = match self.spare_normal.take() {
            Some(n) => n,
            None => {
                let u: f64 = self.rng.next_f64().max(1e-12);
                let v: f64 = self.rng.next_f64();
                let r = (-2.0 * u.ln()).sqrt();
                let (sin, cos) = (2.0 * std::f64::consts::PI * v).sin_cos();
                self.spare_normal = Some(r * sin);
                r * cos
            }
        };
        1.0 + n.abs() * sigma
    }

    /// Uniform draw in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    /// Uniform index in `0..n`.
    pub fn index(&mut self, n: usize) -> usize {
        self.rng.below(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed_and_stream() {
        let mut a = SimRng::new(42, "fs");
        let mut b = SimRng::new(42, "fs");
        for _ in 0..10 {
            assert_eq!(a.jitter(0.05).to_bits(), b.jitter(0.05).to_bits());
        }
    }

    #[test]
    fn streams_are_independent() {
        let mut a = SimRng::new(42, "fs");
        let mut b = SimRng::new(42, "net");
        let va: Vec<u64> = (0..8).map(|_| a.jitter(0.5).to_bits()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.jitter(0.5).to_bits()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn jitter_bounds() {
        let mut r = SimRng::new(7, "x");
        for _ in 0..1000 {
            let j = r.jitter(0.02);
            assert!((0.98..=1.02).contains(&j), "jitter {j} out of bounds");
        }
    }

    #[test]
    fn spike_is_at_least_one() {
        let mut r = SimRng::new(9, "spike");
        for _ in 0..1000 {
            assert!(r.spike(0.3) >= 1.0);
        }
    }

    #[test]
    fn index_in_range() {
        let mut r = SimRng::new(1, "idx");
        for _ in 0..100 {
            assert!(r.index(5) < 5);
        }
    }
}
