//! Conservative parallel DES over lookahead domains.
//!
//! The calendar queue ([`EventQueue`]) is deterministic but
//! single-threaded: a million-node `fig1-scale` deploy or a 16-worker
//! build farm burns one core however many the machine has.  This
//! module partitions a cell's event population into **lookahead
//! domains** — disjoint slices of the simulated cluster (node ranges,
//! node classes, sessions, workers) — and runs a conservative
//! parallel simulation across them in the Chandy–Misra–Bryant style:
//!
//! * each domain owns a private [`EventQueue`], so intra-domain
//!   scheduling stays the O(1) calendar hot path;
//! * a **lookahead bound** `L` (for the container tiers: the WAN
//!   registry latency, [`wan_lookahead`](crate::net::wan_lookahead) —
//!   no cross-domain effect can land sooner than a registry round
//!   trip) lets every domain advance to the horizon
//!   `LBTS = min(domain heads) + L` without waiting on its peers;
//! * domains with nothing due before the horizon contribute only
//!   their lower-bound time stamp — the classic **null message**,
//!   counted in [`PdesStats::null_msgs`];
//! * the per-window drains run on scoped threads (one per domain)
//!   when the population is large enough to pay for them, and their
//!   results are **merged deterministically**.
//!
//! ## The determinism contract survives
//!
//! Every event carries a **global push sequence number** (`gseq`),
//! assigned in push order exactly like the serial queue's `seq`.  The
//! merge pops the minimum `(time, gseq)` over the window buffer and
//! the live domain heads, so the pop stream is **byte-for-byte the
//! serial `(time, seq)` stream for any domain count and any domain
//! mapping** — partitioning affects only which core does the work,
//! never the answer.  Late pushes that land inside an already-drained
//! window (a consumer scheduling new work mid-drain) are caught by the
//! live-head comparison and pop in their correct slot
//! ([`PdesStats::preemptions`] counts them).  `tests/queue_equivalence.rs`
//! diff-tests partitioned pop streams against the serial reference on
//! randomized workloads, and the scenario renders are CI-gated
//! byte-identical across `--domains {1,2,4}` (`ci/render_diff.sh`).
//!
//! [`CellQueue`] is the front consumers use: `--domains 1` selects the
//! plain serial [`EventQueue`] (the retained reference path), anything
//! larger the partitioned engine — mirroring the per-rank vs collapsed
//! split in the distribution tier.

use std::collections::VecDeque;
use std::thread;

use super::stats::QueueStats;
use super::{Duration, EventQueue, VirtualTime};

/// Queued events required before a window drain recruits threads: a
/// scoped spawn costs ~10 µs, so small populations drain serially
/// (identical results either way — the threshold is a pure perf knob).
const PARALLEL_DRAIN_MIN: usize = 4096;

/// FNV-1a offset basis (used by the deterministic drain digest).
const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
/// FNV-1a prime.
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Fold one value into an FNV-1a accumulator (order-sensitive, so a
/// digest pins the exact merge order, not just the event multiset).
fn fnv_fold(acc: u64, value: u64) -> u64 {
    let mut h = acc;
    for b in value.to_le_bytes() {
        h = (h ^ u64::from(b)).wrapping_mul(FNV_PRIME);
    }
    h
}

/// Observability counters for one [`PartitionedQueue`] lifetime.
///
/// These describe the *parallel machinery* — windows, null messages,
/// cross-domain traffic — and are reported beside the semantic
/// [`QueueStats`].  None of them feed back into scheduling.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct PdesStats {
    /// Lookahead domains the queue was built with.
    pub domains: usize,
    /// LBTS windows advanced (one horizon computation + drain each).
    pub windows: u64,
    /// Windows whose drain ran on scoped threads (the rest stayed
    /// serial because the population was below the threshold).
    pub parallel_windows: u64,
    /// Events moved from domain queues into the merge buffer by
    /// window drains.
    pub drained: u64,
    /// Domain-windows that contributed no event, only their lower
    /// bound time stamp (the conservative null message).
    pub null_msgs: u64,
    /// Pushes routed to a different domain than the one whose event
    /// the consumer was processing (cross-domain messages).
    pub cross_msgs: u64,
    /// Pushes that stayed inside the processing domain.
    pub local_msgs: u64,
    /// Pushes that landed earlier than already-drained window events
    /// (served correctly via the live-head comparison).
    pub preemptions: u64,
}

impl PdesStats {
    /// Fraction of pushes that crossed a domain boundary, in `[0, 1]`
    /// (0.0 before any push).  High rates mean the domain mapping
    /// fights the workload's communication structure.
    pub fn cross_rate(&self) -> f64 {
        let total = self.cross_msgs + self.local_msgs;
        if total == 0 {
            0.0
        } else {
            self.cross_msgs as f64 / total as f64
        }
    }

    /// One-line summary for reports and bench output.
    pub fn render(&self) -> String {
        format!(
            "pdes: {} domain(s), {} window(s) ({} threaded), {} drained, \
             {} null msg(s), {} cross / {} local push(es), {} preemption(s)",
            self.domains,
            self.windows,
            self.parallel_windows,
            self.drained,
            self.null_msgs,
            self.cross_msgs,
            self.local_msgs,
            self.preemptions,
        )
    }
}

/// Drain every event due at or before `horizon` out of one domain
/// queue, preserving the domain's own `(time, gseq)` order.
fn drain_until<T>(
    q: &mut EventQueue<(u64, T)>,
    horizon: VirtualTime,
) -> Vec<(VirtualTime, u64, T)> {
    let mut out = Vec::new();
    while q.peek_time().is_some_and(|t| t <= horizon) {
        let (t, (g, ev)) = q.pop().expect("peeked event pops");
        out.push((t, g, ev));
    }
    out
}

/// A conservatively parallel event queue: per-domain calendar queues
/// advanced window-by-window under a lookahead bound, with a
/// deterministic `(time, gseq)` merge that reproduces the serial
/// [`EventQueue`] pop stream byte-for-byte (module docs tell the full
/// story).
#[derive(Clone, Debug)]
pub struct PartitionedQueue<T> {
    /// One calendar queue per lookahead domain; payloads carry their
    /// global push sequence number so the merge can break time ties
    /// exactly as the serial queue does.
    domains: Vec<EventQueue<(u64, T)>>,
    /// Cached `(time, gseq)` of each domain's earliest live event
    /// (`None` = empty).  Kept exact on every push/pop so the merge's
    /// per-pop live minimum is O(domains), not O(buckets).
    heads: Vec<Option<(VirtualTime, u64)>>,
    /// The lookahead bound `L`: no cross-domain push can land earlier
    /// than `now + L`, so every domain may drain to `LBTS + L`.
    lookahead: Duration,
    /// Window events already drained out of the domain queues, merged
    /// ascending by `(time, gseq)`; entries remember their domain.
    buffer: VecDeque<(VirtualTime, u64, usize, T)>,
    /// Global push counter — the serial queue's `seq`, reproduced.
    gseq: u64,
    /// Live events (buffered-but-unpopped ones still count).
    len: usize,
    /// High-water mark of `len` (matches the serial trajectory).
    depth_hwm: usize,
    /// Lifetime pops.
    pops: u64,
    /// Domain of the most recently popped event (cross-message
    /// accounting: a consumer's pushes are attributed to it).
    current_domain: Option<usize>,
    /// Parallel-machinery counters.
    stats: PdesStats,
}

impl<T: Send> PartitionedQueue<T> {
    /// A queue over `domains` lookahead domains (clamped to >= 1) with
    /// lookahead bound `lookahead`, pre-sized for `cap` in-flight
    /// events split across the domains.
    pub fn new(domains: usize, lookahead: Duration, cap: usize) -> Self {
        let n = domains.max(1);
        let per = cap / n + 1;
        PartitionedQueue {
            domains: (0..n).map(|_| EventQueue::with_capacity(per)).collect(),
            heads: vec![None; n],
            lookahead,
            buffer: VecDeque::new(),
            gseq: 0,
            len: 0,
            depth_hwm: 0,
            pops: 0,
            current_domain: None,
            stats: PdesStats {
                domains: n,
                ..PdesStats::default()
            },
        }
    }

    /// Number of lookahead domains.
    pub fn domains(&self) -> usize {
        self.domains.len()
    }

    /// The lookahead bound the horizons advance by.
    pub fn lookahead(&self) -> Duration {
        self.lookahead
    }

    /// Schedule `event` at `time` in `domain` (wrapped modulo the
    /// domain count, so callers can pass a raw node/class/session
    /// index).  The event's pop position is independent of the domain:
    /// routing affects which core drains it, never the order.
    pub fn push(&mut self, domain: usize, time: VirtualTime, event: T) {
        let d = domain % self.domains.len();
        match self.current_domain {
            Some(cd) if cd != d => self.stats.cross_msgs += 1,
            _ => self.stats.local_msgs += 1,
        }
        if let Some(&(last, _, _, _)) = self.buffer.back() {
            if time < last {
                self.stats.preemptions += 1;
            }
        }
        let g = self.gseq;
        self.gseq += 1;
        self.domains[d].push(time, (g, event));
        if self.heads[d].map_or(true, |head| (time, g) < head) {
            self.heads[d] = Some((time, g));
        }
        self.len += 1;
        self.depth_hwm = self.depth_hwm.max(self.len);
    }

    /// Schedule a whole batch of `(domain, time, event)` entries.
    ///
    /// Exactly the serial [`EventQueue::push_batch`] contract: the
    /// batch is stably sorted by time **globally** (across domains)
    /// and sequence numbers are assigned in sorted order, so events
    /// earlier in the batch keep FIFO priority among equal timestamps
    /// no matter which domains they route to.
    pub fn push_batch(&mut self, mut batch: Vec<(usize, VirtualTime, T)>) {
        if batch.is_empty() {
            return;
        }
        batch.sort_by_key(|e| e.1);
        for (domain, time, event) in batch {
            self.push(domain, time, event);
        }
    }

    /// Pop the earliest event — globally, in `(time, gseq)` order,
    /// byte-identical to the serial pop stream.
    pub fn pop(&mut self) -> Option<(VirtualTime, T)> {
        loop {
            let buffered = self.buffer.front().map(|&(t, g, _, _)| (t, g));
            let live = self.min_live();
            match (buffered, live) {
                (None, None) => return None,
                // A late push beat the drained window: serve it live.
                (Some(b), Some((lt, lg, d))) if (lt, lg) < b => return Some(self.pop_live(d)),
                (Some(_), _) => {
                    let (t, _, d, ev) = self.buffer.pop_front().expect("buffered event");
                    self.finish_pop(d);
                    return Some((t, ev));
                }
                (None, Some(_)) => self.refill(),
            }
        }
    }

    /// Timestamp of the next event without removing it.
    pub fn peek_time(&self) -> Option<VirtualTime> {
        let buffered = self.buffer.front().map(|&(t, g, _, _)| (t, g));
        let live = self.min_live().map(|(t, g, _)| (t, g));
        match (buffered, live) {
            (Some(a), Some(b)) => Some(a.min(b).0),
            (Some(a), None) => Some(a.0),
            (None, Some(b)) => Some(b.0),
            (None, None) => None,
        }
    }

    /// Number of queued events (window-buffered ones included).
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether no events are queued.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Semantic scheduler counters, serial-identical by construction:
    /// `depth`/`depth_hwm`/`pushes`/`pops` track the wrapper-level
    /// push/pop trajectory, which is the same sequence the serial
    /// queue sees.  The geometry fields (buckets, width, resizes,
    /// sparse jumps) are summed over the per-domain calendars — they
    /// describe this engine's internals and are *not* part of the
    /// determinism contract (reports that must stay byte-identical
    /// across `--domains` render only the semantic counters).
    pub fn stats(&self) -> QueueStats {
        let mut buckets = 0;
        let mut occupied = 0;
        let mut width = 0;
        let mut resizes = 0;
        let mut jumps = 0;
        for q in &self.domains {
            let s = q.stats();
            buckets += s.buckets;
            occupied += s.occupied_buckets;
            width = s.bucket_width_ns.max(width);
            resizes += s.resizes;
            jumps += s.sparse_jumps;
        }
        QueueStats {
            depth: self.len,
            depth_hwm: self.depth_hwm,
            pushes: self.gseq,
            pops: self.pops,
            buckets,
            occupied_buckets: occupied,
            bucket_width_ns: width,
            resizes,
            sparse_jumps: jumps,
        }
    }

    /// Snapshot of the parallel-machinery counters.
    pub fn pdes_stats(&self) -> PdesStats {
        self.stats
    }

    /// Drain the whole queue, computing `work(time, &event)` for every
    /// event *inside its domain's drain thread* and folding the
    /// results into an FNV-1a digest in global `(time, gseq)` order.
    ///
    /// This is the parallel payoff path for workloads that are fully
    /// scheduled up front (fan-out waves, open-loop arrival streams):
    /// the per-event work runs domain-parallel, yet the returned
    /// digest is byte-identical to folding the serial pop stream —
    /// `benches/pdes.rs` records the serial-vs-domains speedup and
    /// asserts the digests agree.  Events already moved to the window
    /// buffer are folded first (they precede everything live).
    pub fn drain_fold_hash<W>(&mut self, work: W) -> u64
    where
        W: Fn(VirtualTime, &T) -> u64 + Sync,
    {
        let mut digest = FNV_OFFSET;
        while let Some((t, _, d, ev)) = self.buffer.pop_front() {
            digest = fnv_fold(digest, work(t, &ev));
            self.finish_pop(d);
        }
        loop {
            let Some((min_t, _, _)) = self.min_live() else {
                return digest;
            };
            let horizon = min_t + self.lookahead;
            self.stats.windows += 1;
            let parallel = self.domains.len() > 1 && self.len >= PARALLEL_DRAIN_MIN;
            let per_domain: Vec<Vec<(VirtualTime, u64, u64)>> = if parallel {
                self.stats.parallel_windows += 1;
                let w = &work;
                thread::scope(|s| {
                    let handles: Vec<_> = self
                        .domains
                        .iter_mut()
                        .map(|q| {
                            s.spawn(move || {
                                let mut out = Vec::new();
                                while q.peek_time().is_some_and(|t| t <= horizon) {
                                    let (t, (g, ev)) = q.pop().expect("peeked event pops");
                                    out.push((t, g, w(t, &ev)));
                                }
                                out
                            })
                        })
                        .collect();
                    handles
                        .into_iter()
                        .map(|h| h.join().expect("domain drain thread"))
                        .collect()
                })
            } else {
                self.domains
                    .iter_mut()
                    .map(|q| {
                        drain_until(q, horizon)
                            .into_iter()
                            .map(|(t, g, ev)| (t, g, work(t, &ev)))
                            .collect()
                    })
                    .collect()
            };
            let mut window: Vec<(VirtualTime, u64, u64)> = Vec::new();
            for (d, part) in per_domain.into_iter().enumerate() {
                if part.is_empty() {
                    self.stats.null_msgs += 1;
                }
                self.heads[d] = self.domains[d].peek().map(|(t, &(g, _))| (t, g));
                window.extend(part);
            }
            window.sort_unstable_by_key(|&(t, g, _)| (t, g));
            self.stats.drained += window.len() as u64;
            for &(_, _, r) in &window {
                digest = fnv_fold(digest, r);
                self.len -= 1;
                self.pops += 1;
            }
        }
    }

    /// Cached minimum live `(time, gseq)` with its domain — O(domains).
    fn min_live(&self) -> Option<(VirtualTime, u64, usize)> {
        let mut best: Option<(VirtualTime, u64, usize)> = None;
        for (d, head) in self.heads.iter().enumerate() {
            if let Some((t, g)) = *head {
                if best.map_or(true, |(bt, bg, _)| (t, g) < (bt, bg)) {
                    best = Some((t, g, d));
                }
            }
        }
        best
    }

    /// Pop domain `d`'s head directly (a preempting late push).
    fn pop_live(&mut self, d: usize) -> (VirtualTime, T) {
        let (t, (_, ev)) = self.domains[d].pop().expect("live head pops");
        self.heads[d] = self.domains[d].peek().map(|(ht, &(hg, _))| (ht, hg));
        self.finish_pop(d);
        (t, ev)
    }

    /// Shared pop bookkeeping (both the buffered and live paths).
    fn finish_pop(&mut self, d: usize) {
        self.len -= 1;
        self.pops += 1;
        self.current_domain = Some(d);
    }

    /// Advance one LBTS window: drain every domain to
    /// `min(live heads) + lookahead` (threaded when the population
    /// pays for it) and merge the results into the buffer in global
    /// `(time, gseq)` order.  Guaranteed progress: the domain holding
    /// the minimum always contributes at least that event.
    fn refill(&mut self) {
        let Some((min_t, _, _)) = self.min_live() else {
            return;
        };
        let horizon = min_t + self.lookahead;
        self.stats.windows += 1;
        let parallel = self.domains.len() > 1 && self.len >= PARALLEL_DRAIN_MIN;
        let per_domain: Vec<Vec<(VirtualTime, u64, T)>> = if parallel {
            self.stats.parallel_windows += 1;
            thread::scope(|s| {
                let handles: Vec<_> = self
                    .domains
                    .iter_mut()
                    .map(|q| s.spawn(move || drain_until(q, horizon)))
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("domain drain thread"))
                    .collect()
            })
        } else {
            self.domains
                .iter_mut()
                .map(|q| drain_until(q, horizon))
                .collect()
        };
        let mut window: Vec<(VirtualTime, u64, usize, T)> = Vec::new();
        for (d, part) in per_domain.into_iter().enumerate() {
            if part.is_empty() {
                self.stats.null_msgs += 1;
            }
            self.heads[d] = self.domains[d].peek().map(|(t, &(g, _))| (t, g));
            for (t, g, ev) in part {
                window.push((t, g, d, ev));
            }
        }
        window.sort_unstable_by_key(|&(t, g, _, _)| (t, g));
        self.stats.drained += window.len() as u64;
        self.buffer.extend(window);
    }
}

/// The queue a simulation cell schedules through: the serial
/// [`EventQueue`] reference at `--domains 1`, the conservatively
/// parallel [`PartitionedQueue`] above it — same pop stream either
/// way, so the choice is a pure performance knob (exactly the
/// per-rank-vs-collapsed split the distribution tier already uses).
#[derive(Clone, Debug)]
pub enum CellQueue<T> {
    /// The single serial calendar queue (reference path).
    Serial(EventQueue<T>),
    /// Per-domain queues under the conservative parallel merge.
    Partitioned(PartitionedQueue<T>),
}

impl<T: Send> CellQueue<T> {
    /// A cell queue over `domains` lookahead domains (<= 1 selects the
    /// serial reference), with lookahead bound `lookahead`, pre-sized
    /// for `cap` in-flight events.
    pub fn new(domains: usize, lookahead: Duration, cap: usize) -> Self {
        if domains <= 1 {
            CellQueue::Serial(EventQueue::with_capacity(cap))
        } else {
            CellQueue::Partitioned(PartitionedQueue::new(domains, lookahead, cap))
        }
    }

    /// Schedule `event` at `time`; `domain` is a raw partition index
    /// (node, class, session, worker — wrapped modulo the domain
    /// count) and is ignored on the serial path.
    pub fn push(&mut self, domain: usize, time: VirtualTime, event: T) {
        match self {
            CellQueue::Serial(q) => q.push(time, event),
            CellQueue::Partitioned(q) => q.push(domain, time, event),
        }
    }

    /// Schedule a batch of `(domain, time, event)` entries under the
    /// [`EventQueue::push_batch`] contract (global stable sort by
    /// time; FIFO priority by batch position among ties).
    pub fn push_batch(&mut self, batch: Vec<(usize, VirtualTime, T)>) {
        match self {
            CellQueue::Serial(q) => {
                q.push_batch(batch.into_iter().map(|(_, t, ev)| (t, ev)).collect())
            }
            CellQueue::Partitioned(q) => q.push_batch(batch),
        }
    }

    /// Pop the earliest event in global `(time, seq)` order.
    pub fn pop(&mut self) -> Option<(VirtualTime, T)> {
        match self {
            CellQueue::Serial(q) => q.pop(),
            CellQueue::Partitioned(q) => q.pop(),
        }
    }

    /// Timestamp of the next event without removing it.
    pub fn peek_time(&self) -> Option<VirtualTime> {
        match self {
            CellQueue::Serial(q) => q.peek_time(),
            CellQueue::Partitioned(q) => q.peek_time(),
        }
    }

    /// Number of queued events.
    pub fn len(&self) -> usize {
        match self {
            CellQueue::Serial(q) => q.len(),
            CellQueue::Partitioned(q) => q.len(),
        }
    }

    /// Whether no events are queued.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Scheduler counters: the semantic fields
    /// (`depth`/`depth_hwm`/`pushes`/`pops`) are byte-identical across
    /// domain counts; see [`PartitionedQueue::stats`] for the geometry
    /// caveat.
    pub fn stats(&self) -> QueueStats {
        match self {
            CellQueue::Serial(q) => q.stats(),
            CellQueue::Partitioned(q) => q.stats(),
        }
    }

    /// The parallel-machinery counters, when partitioned.
    pub fn pdes(&self) -> Option<PdesStats> {
        match self {
            CellQueue::Serial(_) => None,
            CellQueue::Partitioned(q) => Some(q.pdes_stats()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(ns: u64) -> VirtualTime {
        VirtualTime::ZERO + Duration::from_nanos(ns)
    }

    const L: Duration = Duration::from_nanos(50);

    /// Serial pop stream of the same (time, payload) push sequence.
    fn serial_stream(pushes: &[(usize, u64, u32)]) -> Vec<(VirtualTime, u32)> {
        let mut q = EventQueue::new();
        for &(_, ns, ev) in pushes {
            q.push(t(ns), ev);
        }
        std::iter::from_fn(move || q.pop()).collect()
    }

    fn partitioned_stream(domains: usize, pushes: &[(usize, u64, u32)]) -> Vec<(VirtualTime, u32)> {
        let mut q = PartitionedQueue::new(domains, L, pushes.len());
        for &(d, ns, ev) in pushes {
            q.push(d, t(ns), ev);
        }
        std::iter::from_fn(move || q.pop()).collect()
    }

    #[test]
    fn pop_stream_matches_serial_for_any_domain_count() {
        // ties at the horizon, a sparse outlier, interleaved domains
        let pushes: Vec<(usize, u64, u32)> = vec![
            (0, 100, 0),
            (1, 100, 1), // cross-domain tie: gseq must break it
            (2, 150, 2), // exactly at domain 0's first horizon (100+50)
            (0, 100, 3),
            (3, 5_000, 4), // beyond every early horizon
            (1, 0, 5),
            (2, 151, 6), // just past the horizon
        ];
        let reference = serial_stream(&pushes);
        for domains in [1, 2, 3, 4, 8] {
            assert_eq!(
                partitioned_stream(domains, &pushes),
                reference,
                "domains={domains}"
            );
        }
    }

    #[test]
    fn empty_domain_and_all_in_one_domain_are_fine() {
        // everything routes to domain 0 of 4: three permanently idle
        // domains emit only null messages
        let pushes: Vec<(usize, u64, u32)> =
            (0..200).map(|i| (0usize, i * 7 % 90, i as u32)).collect();
        let reference = serial_stream(&pushes);
        let mut q = PartitionedQueue::new(4, L, pushes.len());
        for &(d, ns, ev) in &pushes {
            q.push(d, t(ns), ev);
        }
        let got: Vec<_> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(got, reference);
        let s = q.pdes_stats();
        assert!(s.null_msgs >= 3, "idle domains must show as null messages");
        assert!(s.windows >= 1);
    }

    #[test]
    fn push_batch_keeps_global_fifo_priority_across_domains() {
        let batch: Vec<(usize, VirtualTime, u32)> = vec![
            (1, t(30), 0),
            (0, t(10), 1),
            (2, t(10), 2), // same instant, later in batch: pops after 1
            (1, t(10), 3),
        ];
        let mut serial = EventQueue::new();
        serial.push_batch(batch.iter().map(|&(_, tt, ev)| (tt, ev)).collect());
        let reference: Vec<_> = std::iter::from_fn(|| serial.pop()).collect();
        for domains in [2, 3] {
            let mut q = PartitionedQueue::new(domains, L, 8);
            q.push_batch(batch.clone());
            let got: Vec<_> = std::iter::from_fn(|| q.pop()).collect();
            assert_eq!(got, reference, "domains={domains}");
        }
    }

    #[test]
    fn late_pushes_preempt_the_drained_window() {
        let mut q = PartitionedQueue::new(2, Duration::from_nanos(1_000), 16);
        q.push(0, t(100), 0u32);
        q.push(1, t(200), 1);
        q.push(0, t(300), 2);
        // first pop drains the whole window [100, 1100] into the buffer
        assert_eq!(q.pop(), Some((t(100), 0)));
        // now schedule work *inside* the drained span — it must pop in
        // its correct slot, before the buffered t=200/t=300 events
        q.push(1, t(150), 9);
        assert_eq!(q.pop(), Some((t(150), 9)));
        assert_eq!(q.pop(), Some((t(200), 1)));
        assert_eq!(q.pop(), Some((t(300), 2)));
        assert_eq!(q.pop(), None);
        assert_eq!(q.pdes_stats().preemptions, 1);
    }

    #[test]
    fn semantic_stats_match_the_serial_trajectory() {
        let pushes: Vec<(usize, u64, u32)> =
            (0..500).map(|i| (i % 5, (i * 31) % 400, i as u32)).collect();
        let mut serial = EventQueue::new();
        let mut part = PartitionedQueue::new(4, L, 64);
        for &(d, ns, ev) in &pushes {
            serial.push(t(ns), ev);
            part.push(d, t(ns), ev);
        }
        for _ in 0..200 {
            assert_eq!(serial.pop(), part.pop());
        }
        let (a, b) = (serial.stats(), part.stats());
        assert_eq!(a.depth, b.depth);
        assert_eq!(a.depth_hwm, b.depth_hwm);
        assert_eq!(a.pushes, b.pushes);
        assert_eq!(a.pops, b.pops);
    }

    #[test]
    fn drain_fold_hash_is_domain_invariant() {
        let work = |tt: VirtualTime, ev: &u32| {
            tt.0.wrapping_mul(0x9e37_79b9_7f4a_7c15) ^ u64::from(*ev)
        };
        let pushes: Vec<(usize, u64, u32)> =
            (0..3_000).map(|i| (i % 7, (i * 131) % 5_000, i as u32)).collect();
        // serial reference digest over the serial pop stream
        let mut serial = EventQueue::new();
        for &(_, ns, ev) in &pushes {
            serial.push(t(ns), ev);
        }
        let mut reference = FNV_OFFSET;
        while let Some((tt, ev)) = serial.pop() {
            reference = fnv_fold(reference, work(tt, &ev));
        }
        for domains in [1, 2, 4] {
            let mut q = PartitionedQueue::new(domains, L, pushes.len());
            for &(d, ns, ev) in &pushes {
                q.push(d, t(ns), ev);
            }
            assert_eq!(q.drain_fold_hash(work), reference, "domains={domains}");
            assert!(q.is_empty());
        }
    }

    #[test]
    fn cross_and_local_messages_are_counted() {
        let mut q = PartitionedQueue::new(2, L, 8);
        q.push(0, t(10), 0u32); // no current domain yet: local
        assert_eq!(q.pop(), Some((t(10), 0)));
        q.push(0, t(20), 1); // same domain as the popped event
        q.push(1, t(30), 2); // crosses to domain 1
        let s = q.pdes_stats();
        assert_eq!(s.local_msgs, 2);
        assert_eq!(s.cross_msgs, 1);
        assert!(s.cross_rate() > 0.3 && s.cross_rate() < 0.34);
        assert!(s.render().contains("2 domain(s)"));
    }

    #[test]
    fn cell_queue_selects_serial_at_one_domain() {
        let q: CellQueue<u32> = CellQueue::new(1, L, 4);
        assert!(matches!(q, CellQueue::Serial(_)));
        assert!(q.pdes().is_none());
        let q: CellQueue<u32> = CellQueue::new(4, L, 4);
        assert!(matches!(q, CellQueue::Partitioned(_)));
        assert_eq!(q.pdes().expect("partitioned").domains, 4);
    }

    #[test]
    fn cell_queue_paths_agree() {
        let batch: Vec<(usize, VirtualTime, u32)> =
            (0..100).map(|i| (i, t((i as u64 * 37) % 200), i as u32)).collect();
        let mut serial: CellQueue<u32> = CellQueue::new(1, L, 100);
        let mut part: CellQueue<u32> = CellQueue::new(3, L, 100);
        serial.push_batch(batch.clone());
        part.push_batch(batch);
        assert_eq!(serial.len(), part.len());
        assert_eq!(serial.peek_time(), part.peek_time());
        loop {
            let (a, b) = (serial.pop(), part.pop());
            assert_eq!(a, b);
            if a.is_none() {
                break;
            }
        }
        let (a, b) = (serial.stats(), part.stats());
        assert_eq!(
            (a.pushes, a.pops, a.depth, a.depth_hwm),
            (b.pushes, b.pops, b.depth, b.depth_hwm)
        );
    }
}
