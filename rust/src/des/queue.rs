//! Deterministic timed event queue.
//!
//! A `BinaryHeap` keyed on `(time, sequence)`: events scheduled for the
//! same instant pop in the order they were pushed, so a simulation's
//! event interleaving is a pure function of its inputs and seed.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::VirtualTime;

/// A priority queue of `(VirtualTime, T)` events with FIFO tie-breaking.
pub struct EventQueue<T> {
    heap: BinaryHeap<Reverse<(VirtualTime, u64, usize)>>,
    // Events are stored out-of-line so `T` needs no `Ord`.
    slots: Vec<Option<T>>,
    free: Vec<usize>,
    seq: u64,
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> EventQueue<T> {
    /// An empty queue.
    pub fn new() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            slots: Vec::new(),
            free: Vec::new(),
            seq: 0,
        }
    }

    /// A queue pre-sized for `cap` in-flight events: the heap and the
    /// out-of-line slot store are reserved up front, so a long
    /// simulation that never exceeds `cap` pending events performs no
    /// mid-run regrowth (regrowth churn showed up in the event-queue
    /// micro bench; see EXPERIMENTS.md §Perf).
    pub fn with_capacity(cap: usize) -> Self {
        EventQueue {
            heap: BinaryHeap::with_capacity(cap),
            slots: Vec::with_capacity(cap),
            free: Vec::with_capacity(cap),
            seq: 0,
        }
    }

    /// Events the queue can hold before any of its stores reallocates.
    pub fn capacity(&self) -> usize {
        self.heap.capacity().min(self.slots.capacity()).min(self.free.capacity())
    }

    /// Schedule `event` at `time`.
    pub fn push(&mut self, time: VirtualTime, event: T) {
        let slot = match self.free.pop() {
            Some(i) => {
                self.slots[i] = Some(event);
                i
            }
            None => {
                self.slots.push(Some(event));
                self.slots.len() - 1
            }
        };
        self.heap.push(Reverse((time, self.seq, slot)));
        self.seq += 1;
    }

    /// Pop the earliest event (FIFO among equal timestamps).
    pub fn pop(&mut self) -> Option<(VirtualTime, T)> {
        let Reverse((time, _, slot)) = self.heap.pop()?;
        let event = self.slots[slot].take().expect("event slot occupied");
        self.free.push(slot);
        Some((time, event))
    }

    /// Timestamp of the next event without removing it.
    pub fn peek_time(&self) -> Option<VirtualTime> {
        self.heap.peek().map(|Reverse((t, _, _))| *t)
    }

    /// Number of queued events.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Whether no events are queued.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::des::Duration;

    fn t(ms: u64) -> VirtualTime {
        VirtualTime::ZERO + Duration::from_millis(ms)
    }

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(t(30), "c");
        q.push(t(10), "a");
        q.push(t(20), "b");
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).map(|(_, e)| e).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn fifo_tiebreak_at_equal_times() {
        let mut q = EventQueue::new();
        for i in 0..100 {
            q.push(t(5), i);
        }
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).map(|(_, e)| e).collect();
        assert_eq!(order, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn peek_does_not_consume() {
        let mut q = EventQueue::new();
        q.push(t(7), ());
        assert_eq!(q.peek_time(), Some(t(7)));
        assert_eq!(q.len(), 1);
        assert!(q.pop().is_some());
        assert!(q.is_empty());
        assert_eq!(q.peek_time(), None);
    }

    #[test]
    fn slot_reuse_after_pop() {
        let mut q = EventQueue::new();
        q.push(t(1), 1);
        q.pop();
        q.push(t(2), 2);
        // the freed slot is reused, not grown
        assert_eq!(q.slots.len(), 1);
        assert_eq!(q.pop().unwrap().1, 2);
    }

    #[test]
    fn with_capacity_is_honoured_without_regrowth() {
        let mut q: EventQueue<u64> = EventQueue::with_capacity(1000);
        assert!(q.capacity() >= 1000);
        let cap_before = q.capacity();
        // a long simulation's worth of churn within the reserved size
        for round in 0..5u64 {
            for i in 0..1000u64 {
                q.push(t(round * 1000 + i % 37), i);
            }
            while q.pop().is_some() {}
        }
        assert_eq!(
            q.capacity(),
            cap_before,
            "staying within capacity must not regrow any store"
        );
        assert_eq!(EventQueue::<u8>::new().capacity(), 0);
    }

    #[test]
    fn interleaved_push_pop() {
        let mut q = EventQueue::new();
        q.push(t(10), 10);
        q.push(t(5), 5);
        assert_eq!(q.pop().unwrap(), (t(5), 5));
        q.push(t(1), 1);
        assert_eq!(q.pop().unwrap(), (t(1), 1));
        assert_eq!(q.pop().unwrap(), (t(10), 10));
    }
}
