//! Deterministic timed event queue — a calendar queue.
//!
//! [`EventQueue`] is the shared hot path of every scenario: each
//! simulated transfer completion, metadata RPC, and fan-out wave passes
//! through it, and the paper-scale cells (98 304-rank halo exchanges,
//! 16 384-node pull storms) push millions of events per figure.  The
//! original `BinaryHeap` implementation paid an `O(log n)` sift per
//! event; this one is a **calendar queue** (a bucketed timing wheel,
//! Brown 1988): events hash into `buckets.len()` day-buckets of
//! `width` nanoseconds each, so insert and extract are O(1) amortised
//! while the queue stays within one calendar "year".  The bucket count
//! and width resize automatically from the observed inter-event
//! spacing, so dense phases (halo storms) and sparse phases (WAN
//! transfers) both keep near-empty buckets.
//!
//! The determinism contract is unchanged and load-bearing: events pop
//! in `(time, sequence)` order, where the sequence counter makes two
//! events at the same instant pop in push order (FIFO tie-break).
//! Simulations therefore remain a pure function of their inputs and
//! seed — `tests/queue_equivalence.rs` diff-tests the pop stream
//! against `HeapEventQueue`, the retained reference implementation
//! (`#[doc(hidden)]`: it exists for the diff tests and
//! `benches/des_queue.rs`, not for simulation code).
//!
//! Events themselves live out-of-line in an arena slab (`slots` +
//! `free` list), so `T` needs no `Ord` and bucket entries are three
//! words: `(time, sequence, slot)`.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::stats::QueueStats;
use super::VirtualTime;

/// Scheduling key: `(time, sequence, slab slot)`.  The sequence makes
/// keys unique and orders equal timestamps FIFO; the slot index is
/// never compared (keys differ in the sequence first).
type Key = (VirtualTime, u64, usize);

/// Fewest buckets the calendar ever uses (a power of two).
const MIN_BUCKETS: usize = 8;

/// Events per bucket the geometry aims for after a rebuild.  A few
/// events per day keeps the table (and its per-bucket allocations) 4×
/// smaller than one-bucket-per-event at the 10⁷-event scale while the
/// bucket heaps stay effectively O(1).
const TARGET_LOAD: usize = 4;

/// Load factor that triggers a growth rebuild.
const GROW_LOAD: usize = 8;

/// Bucket width (ns) before the first rebuild derives one from the
/// actually observed event spacing.
const INITIAL_WIDTH: u64 = 1 << 10;

/// Full-cycle scans tolerated between rebuilds before the calendar
/// re-derives its width: repeated empty years mean events are sparser
/// than the current geometry assumes.
const SPARSE_JUMP_LIMIT: u64 = 4;

/// A calendar-queue scheduler of `(VirtualTime, T)` events with FIFO
/// tie-breaking and O(1) amortised push/pop.
///
/// Drop-in for the previous heap-backed queue: `push`/`pop`/
/// `peek_time`/`len`/`is_empty`/`with_capacity` keep their exact
/// semantics.  New in the calendar era: [`push_batch`] (bulk insert
/// that pre-sorts into buckets) and [`stats`] (scheduler
/// observability, see [`crate::des::stats`]).
///
/// [`push_batch`]: Self::push_batch
/// [`stats`]: Self::stats
#[derive(Clone, Debug)]
pub struct EventQueue<T> {
    /// `buckets[i]` holds every queued key whose day index
    /// (`time / width`) is congruent to `i` modulo the bucket count.
    /// Each bucket is heap-ordered so its minimum is O(1) to see even
    /// when a workload piles ties into one bucket — the worst case
    /// degrades to the old `O(log n)` heap, never to a linear scan.
    buckets: Vec<BinaryHeap<Reverse<Key>>>,
    /// Bucket width in nanoseconds of virtual time (>= 1).
    width: u64,
    /// Bucket the scan is currently parked on.
    cursor: usize,
    /// Exclusive upper time bound (ns) of the cursor bucket's current
    /// day.  `u128`: scanning past late-u64 event times must not
    /// overflow.
    bucket_top: u128,
    /// Queued event count (bucket sizes summed).
    len: usize,
    /// Next sequence number (total pushes so far).
    seq: u64,
    // Arena slab: events are stored out-of-line so `T` needs no `Ord`.
    slots: Vec<Option<T>>,
    free: Vec<usize>,
    // Observability counters, snapshotted by `stats()`.
    depth_hwm: usize,
    pops: u64,
    resizes: u64,
    sparse_jumps: u64,
    jumps_since_rebuild: u64,
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> EventQueue<T> {
    /// An empty queue.
    pub fn new() -> Self {
        Self::with_geometry(MIN_BUCKETS, 0)
    }

    /// A queue pre-sized for `cap` in-flight events: the event slab is
    /// reserved and the calendar starts at its target load for `cap`
    /// events, so a simulation that never exceeds `cap` pending events
    /// performs no slab regrowth and at most the width-adaptation
    /// rebuilds (regrowth churn showed up in the event-queue micro
    /// bench; see EXPERIMENTS.md §Perf).
    pub fn with_capacity(cap: usize) -> Self {
        Self::with_geometry((cap / TARGET_LOAD).next_power_of_two().max(MIN_BUCKETS), cap)
    }

    fn with_geometry(buckets: usize, cap: usize) -> Self {
        EventQueue {
            buckets: (0..buckets).map(|_| BinaryHeap::new()).collect(),
            width: INITIAL_WIDTH,
            cursor: 0,
            bucket_top: u128::from(INITIAL_WIDTH),
            len: 0,
            seq: 0,
            slots: Vec::with_capacity(cap),
            free: Vec::with_capacity(cap),
            depth_hwm: 0,
            pops: 0,
            resizes: 0,
            sparse_jumps: 0,
            jumps_since_rebuild: 0,
        }
    }

    /// Events the slab can hold before reallocating.  (The bucket
    /// table is not counted: it resizes as part of normal width
    /// adaptation.)
    pub fn capacity(&self) -> usize {
        self.slots.capacity().min(self.free.capacity())
    }

    /// Bucket owning instant `t` under the current geometry.
    fn bucket_of(&self, t: VirtualTime) -> usize {
        ((t.0 / self.width) % self.buckets.len() as u64) as usize
    }

    /// Exclusive upper bound of the day containing instant `t`.
    fn day_top(&self, t: VirtualTime) -> u128 {
        (u128::from(t.0) / u128::from(self.width) + 1) * u128::from(self.width)
    }

    /// Schedule `event` at `time`.
    pub fn push(&mut self, time: VirtualTime, event: T) {
        self.insert(time, event);
        if self.len > GROW_LOAD * self.buckets.len() {
            self.rebuild();
        }
    }

    /// Schedule a whole batch of events in one call.
    ///
    /// The batch is pre-sorted by timestamp into the buckets (a stable
    /// sort, so events earlier in the batch keep FIFO priority among
    /// equal timestamps) — ascending keys land at bucket-heap leaves
    /// without sifting, and the geometry is re-derived at most once
    /// for the whole batch instead of once per growth step.  This is
    /// the entry point the batch-shaped consumers use: fan-out waves
    /// in `container::Fleet::deploy` and the server-token reinserts in
    /// [`FifoResource::submit_many`](super::FifoResource::submit_many).
    ///
    /// ```
    /// use harbor::des::{Duration, EventQueue, VirtualTime};
    ///
    /// let t = |ms| VirtualTime::ZERO + Duration::from_millis(ms);
    /// let mut q = EventQueue::new();
    /// q.push_batch(vec![(t(30), "pull"), (t(10), "seed"), (t(10), "check")]);
    /// // time order, FIFO among the two t=10 events:
    /// assert_eq!(q.pop(), Some((t(10), "seed")));
    /// assert_eq!(q.pop(), Some((t(10), "check")));
    /// assert_eq!(q.pop(), Some((t(30), "pull")));
    /// assert_eq!(q.pop(), None);
    /// ```
    pub fn push_batch(&mut self, mut batch: Vec<(VirtualTime, T)>) {
        if batch.is_empty() {
            return;
        }
        batch.sort_by_key(|e| e.0);
        self.slots.reserve(batch.len());
        for (time, event) in batch {
            self.insert(time, event);
        }
        if self.len > GROW_LOAD * self.buckets.len() {
            self.rebuild();
        }
    }

    /// Insert without the growth check (`push`/`push_batch` apply it
    /// once after their insertions).
    fn insert(&mut self, time: VirtualTime, event: T) {
        let slot = match self.free.pop() {
            Some(i) => {
                self.slots[i] = Some(event);
                i
            }
            None => {
                self.slots.push(Some(event));
                self.slots.len() - 1
            }
        };
        // Park the scan on the new event when it precedes everything
        // queued (first event, or a push into the scanned-past past) —
        // the pop scan must never stand ahead of the minimum.
        let day_start = self.bucket_top - u128::from(self.width);
        if self.len == 0 || u128::from(time.0) < day_start {
            self.cursor = self.bucket_of(time);
            self.bucket_top = self.day_top(time);
        }
        let bucket = self.bucket_of(time);
        self.buckets[bucket].push(Reverse((time, self.seq, slot)));
        self.seq += 1;
        self.len += 1;
        self.depth_hwm = self.depth_hwm.max(self.len);
    }

    /// Pop the earliest event (FIFO among equal timestamps).
    pub fn pop(&mut self) -> Option<(VirtualTime, T)> {
        if self.len == 0 {
            return None;
        }
        let mut scanned = 0usize;
        loop {
            // Every instant inside the cursor's current day hashes to
            // the cursor bucket, so a due bucket minimum is the global
            // minimum (ties share a bucket: FIFO is exact).
            if let Some(&Reverse((t, _, _))) = self.buckets[self.cursor].peek() {
                if u128::from(t.0) < self.bucket_top {
                    let Reverse((time, _, slot)) =
                        self.buckets[self.cursor].pop().expect("peeked entry");
                    self.len -= 1;
                    self.pops += 1;
                    let event = self.slots[slot].take().expect("event slot occupied");
                    self.free.push(slot);
                    return Some((time, event));
                }
            }
            self.cursor = (self.cursor + 1) % self.buckets.len();
            self.bucket_top += u128::from(self.width);
            scanned += 1;
            if scanned >= self.buckets.len() {
                // A whole year of empty days: jump the scan straight
                // to the earliest queued event instead of walking the
                // gap day by day.
                self.jump_to_min();
                scanned = 0;
            }
        }
    }

    /// Minimum `(time, seq)` over the bucket heaps, with its bucket
    /// index — O(buckets), the shared scan behind [`peek_time`] and
    /// the sparse jump.
    ///
    /// [`peek_time`]: Self::peek_time
    fn min_entry(&self) -> Option<(VirtualTime, u64, usize)> {
        let mut best: Option<(VirtualTime, u64, usize)> = None;
        for (i, bucket) in self.buckets.iter().enumerate() {
            if let Some(&Reverse((t, s, _))) = bucket.peek() {
                let better = match best {
                    None => true,
                    Some((bt, bs, _)) => (t, s) < (bt, bs),
                };
                if better {
                    best = Some((t, s, i));
                }
            }
        }
        best
    }

    /// Move the scan directly onto the bucket and day of the earliest
    /// queued event; after enough of these the geometry is rebuilt so
    /// the calendar widens to the sparser spacing.
    fn jump_to_min(&mut self) {
        debug_assert!(self.len > 0, "jump on a non-empty queue only");
        let (t, _, i) = self.min_entry().expect("non-empty queue has a minimum");
        self.cursor = i;
        self.bucket_top = self.day_top(t);
        self.sparse_jumps += 1;
        self.jumps_since_rebuild += 1;
        if self.jumps_since_rebuild >= SPARSE_JUMP_LIMIT {
            self.rebuild();
        }
    }

    /// Re-derive the calendar geometry from the queued events:
    /// [`TARGET_LOAD`] events per bucket, width such that one calendar
    /// year spans the queued range — i.e. a day is ~`TARGET_LOAD`
    /// mean inter-event spacings wide (all-ties spans degrade to a
    /// single heap bucket, which is exactly right) — and the scan
    /// parked on the minimum.
    fn rebuild(&mut self) {
        self.resizes += 1;
        self.jumps_since_rebuild = 0;
        let mut keys: Vec<Key> = Vec::with_capacity(self.len);
        for bucket in &mut self.buckets {
            keys.extend(bucket.drain().map(|Reverse(k)| k));
        }
        let n_buckets = (self.len / TARGET_LOAD).next_power_of_two().max(MIN_BUCKETS);
        if self.buckets.len() != n_buckets {
            self.buckets = (0..n_buckets).map(|_| BinaryHeap::new()).collect();
        }
        let (mut lo, mut hi) = (u64::MAX, 0u64);
        for &(t, _, _) in &keys {
            lo = lo.min(t.0);
            hi = hi.max(t.0);
        }
        self.width = if keys.is_empty() || hi == lo {
            INITIAL_WIDTH
        } else {
            ((hi - lo) / n_buckets as u64).max(1)
        };
        if keys.is_empty() {
            self.cursor = 0;
            self.bucket_top = u128::from(self.width);
        } else {
            let min = VirtualTime(lo);
            self.cursor = self.bucket_of(min);
            self.bucket_top = self.day_top(min);
            for key in keys {
                let bucket = self.bucket_of(key.0);
                self.buckets[bucket].push(Reverse(key));
            }
        }
    }

    /// Timestamp of the next event without removing it.
    ///
    /// O(buckets): scans the bucket minima.  This is on a warm path —
    /// [`FifoResource::next_free`](super::FifoResource::next_free)
    /// rides it once per metadata submission — which stays cheap only
    /// because a station's token queue (depth = server count ≤ a few
    /// dozen) never grows past the minimum bucket table; keep that in
    /// mind before making this scan heavier, and prefer `pop` over
    /// polling for large simulation queues.
    pub fn peek_time(&self) -> Option<VirtualTime> {
        self.min_entry().map(|(t, _, _)| t)
    }

    /// The next event's timestamp and a borrow of its payload, without
    /// removing it.  Same O(buckets) scan as [`peek_time`], but it
    /// must find the minimum's *slot*, so it re-walks the bucket heads
    /// instead of reusing the internal `min_entry` (which returns the
    /// bucket index).  Used by the partitioned merge in
    /// [`crate::des::pdes`] to compare domain heads by their embedded
    /// sequence tags.
    ///
    /// [`peek_time`]: Self::peek_time
    pub fn peek(&self) -> Option<(VirtualTime, &T)> {
        let mut best: Option<Key> = None;
        for bucket in &self.buckets {
            if let Some(&Reverse((t, s, slot))) = bucket.peek() {
                let better = match best {
                    None => true,
                    Some((bt, bs, _)) => (t, s) < (bt, bs),
                };
                if better {
                    best = Some((t, s, slot));
                }
            }
        }
        best.map(|(t, _, slot)| {
            (
                t,
                self.slots[slot].as_ref().expect("event slot occupied"),
            )
        })
    }

    /// Number of queued events.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether no events are queued.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Snapshot of the scheduler's observability counters (see
    /// [`crate::des::stats`] for how to read them).
    pub fn stats(&self) -> QueueStats {
        QueueStats {
            depth: self.len,
            depth_hwm: self.depth_hwm,
            pushes: self.seq,
            pops: self.pops,
            buckets: self.buckets.len(),
            occupied_buckets: self.buckets.iter().filter(|b| !b.is_empty()).count(),
            bucket_width_ns: self.width,
            resizes: self.resizes,
            sparse_jumps: self.sparse_jumps,
        }
    }
}

/// The original `BinaryHeap`-backed event queue, retained as the
/// reference implementation.
///
/// Same contract as [`EventQueue`] — pop in `(time, sequence)` order,
/// FIFO among equal timestamps — with an `O(log n)` sift per event.
/// It exists so the calendar queue stays honest: the property suite
/// (`tests/queue_equivalence.rs`) diff-tests pop order against it on
/// randomized workloads, and `benches/des_queue.rs` records the
/// heap-vs-calendar ns/op comparison into `BENCH_micro.json`.  New
/// simulation code should use [`EventQueue`] — this type is kept out
/// of the documented API (`#[doc(hidden)]`) because benches and
/// integration tests are external to the crate and `#[cfg(test)]`
/// would not reach them.
#[doc(hidden)]
pub struct HeapEventQueue<T> {
    heap: BinaryHeap<Reverse<Key>>,
    slots: Vec<Option<T>>,
    free: Vec<usize>,
    seq: u64,
}

impl<T> Default for HeapEventQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> HeapEventQueue<T> {
    /// An empty queue.
    pub fn new() -> Self {
        HeapEventQueue {
            heap: BinaryHeap::new(),
            slots: Vec::new(),
            free: Vec::new(),
            seq: 0,
        }
    }

    /// A queue pre-sized for `cap` in-flight events.
    pub fn with_capacity(cap: usize) -> Self {
        HeapEventQueue {
            heap: BinaryHeap::with_capacity(cap),
            slots: Vec::with_capacity(cap),
            free: Vec::with_capacity(cap),
            seq: 0,
        }
    }

    /// Schedule `event` at `time`.
    pub fn push(&mut self, time: VirtualTime, event: T) {
        let slot = match self.free.pop() {
            Some(i) => {
                self.slots[i] = Some(event);
                i
            }
            None => {
                self.slots.push(Some(event));
                self.slots.len() - 1
            }
        };
        self.heap.push(Reverse((time, self.seq, slot)));
        self.seq += 1;
    }

    /// Schedule a batch (sequentially; the heap has no bucket layout
    /// to exploit — that asymmetry is the point of the comparison).
    pub fn push_batch(&mut self, batch: Vec<(VirtualTime, T)>) {
        for (time, event) in batch {
            self.push(time, event);
        }
    }

    /// Pop the earliest event (FIFO among equal timestamps).
    pub fn pop(&mut self) -> Option<(VirtualTime, T)> {
        let Reverse((time, _, slot)) = self.heap.pop()?;
        let event = self.slots[slot].take().expect("event slot occupied");
        self.free.push(slot);
        Some((time, event))
    }

    /// Timestamp of the next event without removing it.
    pub fn peek_time(&self) -> Option<VirtualTime> {
        self.heap.peek().map(|Reverse((t, _, _))| *t)
    }

    /// Number of queued events.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Whether no events are queued.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::des::Duration;

    fn t(ms: u64) -> VirtualTime {
        VirtualTime::ZERO + Duration::from_millis(ms)
    }

    fn tn(ns: u64) -> VirtualTime {
        VirtualTime::ZERO + Duration::from_nanos(ns)
    }

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(t(30), "c");
        q.push(t(10), "a");
        q.push(t(20), "b");
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).map(|(_, e)| e).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn fifo_tiebreak_at_equal_times() {
        let mut q = EventQueue::new();
        for i in 0..100 {
            q.push(t(5), i);
        }
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).map(|(_, e)| e).collect();
        assert_eq!(order, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn all_events_at_one_timestamp_pop_in_push_order() {
        // degenerate calendar: a thousand events in one bucket-day,
        // half pushed singly, half in batches — the bucket heap must
        // keep exact FIFO order across both paths
        let mut q = EventQueue::new();
        let mut expect = Vec::new();
        let mut next = 0u64;
        while next < 1000 {
            if next % 100 < 50 {
                q.push(t(7), next);
                expect.push(next);
                next += 1;
            } else {
                let batch: Vec<_> = (next..next + 50).map(|i| (t(7), i)).collect();
                expect.extend(next..next + 50);
                q.push_batch(batch);
                next += 50;
            }
        }
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).map(|(_, e)| e).collect();
        assert_eq!(order, expect);
    }

    #[test]
    fn peek_does_not_consume() {
        let mut q = EventQueue::new();
        q.push(t(7), ());
        assert_eq!(q.peek_time(), Some(t(7)));
        assert_eq!(q.peek(), Some((t(7), &())));
        assert_eq!(q.len(), 1);
        assert!(q.pop().is_some());
        assert!(q.is_empty());
        assert_eq!(q.peek_time(), None);
        assert_eq!(q.peek(), None);
    }

    #[test]
    fn peek_returns_the_fifo_minimum_payload() {
        let mut q = EventQueue::new();
        q.push(t(9), "late");
        q.push(t(2), "first");
        q.push(t(2), "second"); // same instant, pushed later
        assert_eq!(q.peek(), Some((t(2), &"first")));
        q.pop();
        assert_eq!(q.peek(), Some((t(2), &"second")));
    }

    #[test]
    fn slot_reuse_after_pop() {
        let mut q = EventQueue::new();
        q.push(t(1), 1);
        q.pop();
        q.push(t(2), 2);
        // the freed slot is reused, not grown
        assert_eq!(q.slots.len(), 1);
        assert_eq!(q.pop().unwrap().1, 2);
    }

    #[test]
    fn with_capacity_is_honoured_without_slab_regrowth() {
        let mut q: EventQueue<u64> = EventQueue::with_capacity(1000);
        assert!(q.capacity() >= 1000);
        let cap_before = q.capacity();
        // a long simulation's worth of churn within the reserved size
        for round in 0..5u64 {
            for i in 0..1000u64 {
                q.push(t(round * 1000 + i % 37), i);
            }
            while q.pop().is_some() {}
        }
        assert_eq!(
            q.capacity(),
            cap_before,
            "staying within capacity must not regrow the event slab"
        );
        assert_eq!(EventQueue::<u8>::new().capacity(), 0);
    }

    #[test]
    fn zero_capacity_construction_works() {
        let mut q: EventQueue<u8> = EventQueue::with_capacity(0);
        assert_eq!(q.capacity(), 0);
        assert!(q.is_empty());
        assert_eq!(q.pop(), None);
        assert_eq!(q.peek_time(), None);
        q.push(t(5), 1);
        assert_eq!(q.pop(), Some((t(5), 1)));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn interleaved_push_pop() {
        let mut q = EventQueue::new();
        q.push(t(10), 10);
        q.push(t(5), 5);
        assert_eq!(q.pop().unwrap(), (t(5), 5));
        q.push(t(1), 1);
        assert_eq!(q.pop().unwrap(), (t(1), 1));
        assert_eq!(q.pop().unwrap(), (t(10), 10));
    }

    #[test]
    fn interleaved_push_during_drain_stays_sorted() {
        // new work scheduled mid-drain (ahead of the queue minimum but
        // behind everything already popped) must slot into order
        let mut q = EventQueue::new();
        q.push_batch((0..100u64).map(|i| (tn(i * 1000), i)).collect());
        let mut popped = 0usize;
        let mut last = VirtualTime::ZERO;
        while let Some((time, i)) = q.pop() {
            assert!(time >= last, "pop order regressed at event {i}");
            last = time;
            popped += 1;
            if i % 7 == 0 && i < 100 {
                q.push(time + Duration::from_nanos(1), 1000 + i);
            }
        }
        assert_eq!(popped, 100 + 15, "every rescheduled event drained");
    }

    #[test]
    fn far_future_outlier_forces_bucket_resize() {
        let mut q = EventQueue::new();
        // dense phase: nanosecond spacing, geometry adapts tight
        for i in 0..100u64 {
            q.push(tn(i), i);
        }
        let dense = q.stats();
        assert!(dense.resizes >= 1, "growth past the load factor rebuilds");
        // far-future outliers: seconds apart, forcing the next rebuild
        // to widen the buckets by orders of magnitude
        for k in 0..80u64 {
            q.push(t(10 + k * 1000), 1000 + k);
        }
        let sparse = q.stats();
        assert!(
            sparse.resizes > dense.resizes,
            "outliers past the dense span must force a resize"
        );
        assert!(
            sparse.bucket_width_ns > dense.bucket_width_ns,
            "width must widen to the sparse spacing: {} -> {}",
            dense.bucket_width_ns,
            sparse.bucket_width_ns
        );
        // and the pop order survives the geometry changes
        let order: Vec<_> = std::iter::from_fn(|| q.pop()).map(|(_, e)| e).collect();
        let expect: Vec<u64> = (0..100).chain(1000..1080).collect();
        assert_eq!(order, expect);
    }

    #[test]
    fn sparse_gap_jumps_straight_to_the_next_event() {
        let mut q = EventQueue::new();
        q.push(tn(0), 0);
        q.push(tn(10), 1);
        // far beyond the initial 8-bucket calendar year
        q.push(t(1), 2);
        assert_eq!(q.pop().unwrap().1, 0);
        assert_eq!(q.pop().unwrap().1, 1);
        assert_eq!(q.pop().unwrap().1, 2);
        assert!(
            q.stats().sparse_jumps >= 1,
            "the millisecond gap must be jumped, not walked"
        );
    }

    #[test]
    fn stats_track_depth_and_resizes() {
        let mut q = EventQueue::new();
        for i in 0..100u64 {
            q.push(tn(i * 50), i);
        }
        let s = q.stats();
        assert_eq!(s.depth, 100);
        assert_eq!(s.depth_hwm, 100);
        assert_eq!(s.pushes, 100);
        assert_eq!(s.pops, 0);
        assert!(s.resizes >= 1);
        assert!(s.buckets >= 16, "grown toward the target load factor");
        assert!(s.occupied_buckets <= s.buckets);
        assert!(s.bucket_width_ns >= 1);
        while q.pop().is_some() {}
        let end = q.stats();
        assert_eq!(end.depth, 0);
        assert_eq!(end.pops, 100);
        assert_eq!(end.depth_hwm, 100, "high-water mark survives the drain");
    }

    #[test]
    fn push_batch_empty_is_a_noop() {
        let mut q: EventQueue<u8> = EventQueue::new();
        q.push_batch(Vec::new());
        assert!(q.is_empty());
        assert_eq!(q.stats().pushes, 0);
    }

    #[test]
    fn heap_reference_agrees_on_a_smoke_sequence() {
        let mut cal = EventQueue::new();
        let mut heap = HeapEventQueue::new();
        let times = [30u64, 10, 10, 50, 0, 10, 40, 0];
        for (i, &ms) in times.iter().enumerate() {
            cal.push(t(ms), i);
            heap.push(t(ms), i);
        }
        assert_eq!(cal.peek_time(), heap.peek_time());
        loop {
            let (a, b) = (cal.pop(), heap.pop());
            assert_eq!(a, b);
            if a.is_none() {
                break;
            }
        }
    }
}
