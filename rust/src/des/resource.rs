//! Multi-server FIFO queueing station.
//!
//! Models contended shared services with deterministic service times:
//! the Lustre metadata server (`c` RPC handlers), a node NIC (1 server,
//! service time = bytes / bandwidth), or registry upload slots.  Work is
//! submitted as `(arrival, service)` pairs; the station returns the
//! completion instant under FIFO discipline, which is all the callers
//! need to advance their own virtual clocks.
//!
//! Each server is a *token* in an [`EventQueue`] timestamped with the
//! instant that server next becomes free, so taking the earliest-free
//! server is a queue pop and releasing it is a push — the same
//! calendar-queue hot path the rest of the simulation schedules
//! through.  [`submit_many`](FifoResource::submit_many) drains the
//! tokens once, runs the whole burst on the scratch copy, and
//! batch-reinserts them via
//! [`EventQueue::push_batch`](super::EventQueue::push_batch).

use super::stats::QueueStats;
use super::{Duration, EventQueue, VirtualTime};

/// A `c`-server FIFO queue with deterministic service times.
#[derive(Debug, Clone)]
pub struct FifoResource {
    /// One token per server: the token's timestamp is the instant that
    /// server next becomes free; the payload is the server id.
    free_at: EventQueue<usize>,
    servers: usize,
    busy: Duration,
    served: u64,
}

/// A token queue with every server idle at the simulation start.
fn idle_tokens(servers: usize) -> EventQueue<usize> {
    let mut q = EventQueue::with_capacity(servers);
    q.push_batch((0..servers).map(|s| (VirtualTime::ZERO, s)).collect());
    q
}

impl FifoResource {
    /// A station with `servers` parallel servers (must be >= 1).
    pub fn new(servers: usize) -> Self {
        assert!(servers >= 1, "resource needs at least one server");
        FifoResource {
            free_at: idle_tokens(servers),
            servers,
            busy: Duration::ZERO,
            served: 0,
        }
    }

    /// Submit a request arriving at `arrival` needing `service` time.
    /// Returns the completion instant. FIFO: the request takes the
    /// earliest-free server, starting no earlier than `arrival`.
    pub fn submit(&mut self, arrival: VirtualTime, service: Duration) -> VirtualTime {
        let (earliest, server) = self.free_at.pop().expect("one token per server");
        let start = earliest.max(arrival);
        let done = start + service;
        self.free_at.push(done, server);
        self.busy += service;
        self.served += 1;
        done
    }

    /// Submit `count` identical requests arriving together at `arrival`,
    /// each needing `service`; returns the completion instant of the
    /// last one. Exactly equivalent to `count` sequential [`submit`]
    /// calls (greedy earliest-free placement is monotone, so the last
    /// submission is also the latest completion), with a single-server
    /// closed form for the NIC/device case and one batched token
    /// reinsert for the multi-server case.
    ///
    /// [`submit`]: Self::submit
    pub fn submit_many(
        &mut self,
        arrival: VirtualTime,
        service: Duration,
        count: u32,
    ) -> VirtualTime {
        if count == 0 {
            return arrival;
        }
        if self.servers == 1 {
            let (earliest, server) = self.free_at.pop().expect("one token per server");
            let start = earliest.max(arrival);
            let done = start + service * u64::from(count);
            self.free_at.push(done, server);
            self.busy += service * u64::from(count);
            self.served += u64::from(count);
            return done;
        }
        // drain every token, run the burst greedily on the scratch
        // copy, and batch-reinsert the updated tokens in one call
        let mut tokens: Vec<(VirtualTime, usize)> = Vec::with_capacity(self.servers);
        while let Some(token) = self.free_at.pop() {
            tokens.push(token);
        }
        let mut last = arrival;
        for _ in 0..count {
            let idx = tokens
                .iter()
                .enumerate()
                .min_by_key(|&(i, &(free, _))| (free, i))
                .map(|(i, _)| i)
                .expect("at least one server");
            let start = tokens[idx].0.max(arrival);
            let done = start + service;
            tokens[idx].0 = done;
            last = last.max(done);
        }
        self.busy += service * u64::from(count);
        self.served += u64::from(count);
        self.free_at.push_batch(tokens);
        last
    }

    /// Total service time delivered (for utilisation accounting).
    pub fn busy_time(&self) -> Duration {
        self.busy
    }

    /// Fraction of `horizon` this station's servers spent busy beyond
    /// the `busy_before` snapshot of [`busy_time`](Self::busy_time),
    /// summed over servers and clamped to \[0, 1\] (a 4-server station
    /// serving 2×`horizon` of work is 50% utilised).  Zero horizon ⇒
    /// 0.0; pass `Duration::ZERO` as the snapshot for lifetime
    /// utilisation.
    pub fn utilisation(&self, busy_before: Duration, horizon: Duration) -> f64 {
        let h = horizon.as_secs_f64() * self.servers as f64;
        if h <= 0.0 {
            0.0
        } else {
            // saturate: a snapshot taken before a reset() would underflow
            let delta =
                Duration::from_nanos(self.busy.as_nanos().saturating_sub(busy_before.as_nanos()));
            (delta.as_secs_f64() / h).clamp(0.0, 1.0)
        }
    }

    /// Number of requests served.
    pub fn served(&self) -> u64 {
        self.served
    }

    /// Earliest instant any server is free.
    pub fn next_free(&self) -> VirtualTime {
        self.free_at.peek_time().unwrap_or(VirtualTime::ZERO)
    }

    /// This station's lower-bound time stamp for conservative parallel
    /// simulation ([`crate::des::pdes`]): no submission processed from
    /// now on can complete before the earliest server frees up, so a
    /// lookahead domain containing this station may be advanced to
    /// `lbts() + lookahead` without waiting on it.  Identical to
    /// [`next_free`](Self::next_free); the alias names the PDES role.
    pub fn lbts(&self) -> VirtualTime {
        self.next_free()
    }

    /// How long a request arriving at `at` would wait before service
    /// starts ([`Duration::ZERO`] when a server is already idle).
    /// This is the queueing-delay view a saturation sweep reports.
    pub fn backlog(&self, at: VirtualTime) -> Duration {
        let free = self.next_free();
        if free > at {
            free - at
        } else {
            Duration::ZERO
        }
    }

    /// Forget all queued state (new simulation phase).
    pub fn reset(&mut self) {
        self.free_at = idle_tokens(self.servers);
        self.busy = Duration::ZERO;
        self.served = 0;
    }

    /// Number of parallel servers.
    pub fn servers(&self) -> usize {
        self.servers
    }

    /// Scheduler counters of the server-token queue (depth is always
    /// the server count — one token per server by construction; the
    /// push/pop totals count how often work moved through the
    /// station's calendar).
    pub fn scheduler_stats(&self) -> QueueStats {
        self.free_at.stats()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(ms: u64) -> VirtualTime {
        VirtualTime::ZERO + Duration::from_millis(ms)
    }

    #[test]
    fn single_server_serialises() {
        let mut r = FifoResource::new(1);
        // three requests arriving together, 10ms each: finish 10/20/30
        assert_eq!(r.submit(t(0), Duration::from_millis(10)), t(10));
        assert_eq!(r.submit(t(0), Duration::from_millis(10)), t(20));
        assert_eq!(r.submit(t(0), Duration::from_millis(10)), t(30));
    }

    #[test]
    fn idle_server_starts_at_arrival() {
        let mut r = FifoResource::new(1);
        assert_eq!(r.submit(t(100), Duration::from_millis(5)), t(105));
    }

    #[test]
    fn two_servers_halve_the_queue() {
        let mut r = FifoResource::new(2);
        let done: Vec<_> = (0..4)
            .map(|_| r.submit(t(0), Duration::from_millis(10)))
            .collect();
        assert_eq!(done, vec![t(10), t(10), t(20), t(20)]);
    }

    #[test]
    fn late_arrival_does_not_wait_for_queue_drain() {
        let mut r = FifoResource::new(1);
        r.submit(t(0), Duration::from_millis(10));
        // arrives after the backlog cleared: starts immediately
        assert_eq!(r.submit(t(50), Duration::from_millis(1)), t(51));
    }

    #[test]
    fn accounting() {
        let mut r = FifoResource::new(3);
        for _ in 0..6 {
            r.submit(t(0), Duration::from_millis(2));
        }
        assert_eq!(r.served(), 6);
        assert_eq!(r.busy_time(), Duration::from_millis(12));
        assert_eq!(r.servers(), 3);
        r.reset();
        assert_eq!(r.served(), 0);
        assert_eq!(r.next_free(), VirtualTime::ZERO);
    }

    #[test]
    fn utilisation_fraction() {
        let mut r = FifoResource::new(2);
        r.submit(t(0), Duration::from_millis(10));
        let horizon = Duration::from_millis(10);
        assert!((r.utilisation(Duration::ZERO, horizon) - 0.5).abs() < 1e-9);
        assert_eq!(r.utilisation(Duration::ZERO, Duration::ZERO), 0.0);
        // only service beyond the snapshot counts
        let snapshot = r.busy_time();
        r.submit(t(0), Duration::from_millis(100));
        assert!((r.utilisation(snapshot, Duration::from_millis(100)) - 0.5).abs() < 1e-9);
        assert_eq!(r.utilisation(snapshot, Duration::from_millis(1)), 1.0, "clamped");
    }

    #[test]
    #[should_panic]
    fn zero_servers_rejected() {
        FifoResource::new(0);
    }

    #[test]
    fn submit_many_matches_sequential_submits() {
        for servers in [1usize, 2, 3, 16] {
            let mut a = FifoResource::new(servers);
            let mut b = FifoResource::new(servers);
            // pre-load with some staggered work so free_at is uneven
            for i in 0..5u64 {
                a.submit(t(i), Duration::from_millis(3 + i));
                b.submit(t(i), Duration::from_millis(3 + i));
            }
            let s = Duration::from_millis(2);
            let many = a.submit_many(t(1), s, 24);
            let mut last = t(0);
            for _ in 0..24 {
                last = last.max(b.submit(t(1), s));
            }
            assert_eq!(many, last, "{servers} servers");
            assert_eq!(a.busy_time(), b.busy_time());
            assert_eq!(a.served(), b.served());
            assert_eq!(a.next_free(), b.next_free());
            // and subsequent behaviour is identical too
            assert_eq!(
                a.submit(t(2), Duration::from_millis(1)),
                b.submit(t(2), Duration::from_millis(1))
            );
        }
    }

    #[test]
    fn lbts_is_the_earliest_server_release() {
        let mut r = FifoResource::new(2);
        assert_eq!(r.lbts(), VirtualTime::ZERO, "idle station bounds at zero");
        r.submit(t(0), Duration::from_millis(10));
        assert_eq!(r.lbts(), VirtualTime::ZERO, "second server still idle");
        r.submit(t(0), Duration::from_millis(4));
        assert_eq!(r.lbts(), t(4), "earliest completion bounds the domain");
        assert_eq!(r.lbts(), r.next_free());
    }

    #[test]
    fn backlog_is_wait_before_service() {
        let mut r = FifoResource::new(1);
        assert_eq!(r.backlog(t(0)), Duration::ZERO, "idle station");
        r.submit(t(0), Duration::from_millis(10));
        assert_eq!(r.backlog(t(0)), Duration::from_millis(10));
        assert_eq!(r.backlog(t(4)), Duration::from_millis(6));
        assert_eq!(r.backlog(t(10)), Duration::ZERO, "drained by then");
        assert_eq!(r.backlog(t(50)), Duration::ZERO);
    }

    #[test]
    fn submit_many_zero_count_is_noop() {
        let mut r = FifoResource::new(2);
        assert_eq!(r.submit_many(t(5), Duration::from_millis(1), 0), t(5));
        assert_eq!(r.served(), 0);
    }

    #[test]
    fn scheduler_stats_expose_token_traffic() {
        let mut r = FifoResource::new(4);
        for _ in 0..10 {
            r.submit(t(0), Duration::from_millis(1));
        }
        let s = r.scheduler_stats();
        assert_eq!(s.depth, 4, "one token per server");
        assert_eq!(s.pushes - s.pops, 4);
        assert!(s.pushes >= 14, "4 idle tokens + 10 reinserts");
    }
}
