//! Scheduler observability counters.
//!
//! The calendar queue is the shared hot path of every scenario, so its
//! behaviour should be *observable*, not asserted: [`QueueStats`] is
//! the snapshot [`EventQueue::stats`](super::EventQueue::stats)
//! returns, surfaced through
//! [`FleetReport`](crate::container::FleetReport) (one queue per
//! deployment wave) and printed by the bench harness
//! (`benches/des_queue.rs`, `benches/fig1_scale.rs`).
//!
//! How to read a snapshot (docs/DES.md walks a full example):
//!
//! * `depth` / `depth_hwm` — pending events now / at the worst moment.
//!   The high-water mark bounds the memory the run needed and tells
//!   you how bursty the workload was.
//! * `buckets`, `occupied_buckets`, `bucket_width_ns` — the calendar
//!   geometry.  A healthy dense phase keeps occupancy
//!   (`occupied_buckets / buckets`) well under 1 with small widths;
//!   sparse phases widen the buckets instead of leaving the scan to
//!   walk empty days.
//! * `resizes` — geometry rebuilds (growth past the load factor, or
//!   width re-derivation after sparse jumps).  Each is O(depth); a hot
//!   loop resizing every few events means the spacing keeps shifting.
//! * `sparse_jumps` — full calendar years scanned without finding a
//!   due event, answered by jumping straight to the minimum.  Large
//!   counts mean the width is (or was) too narrow for the workload.
//! * `pushes` / `pops` — lifetime totals; `pushes - pops == depth`.

/// Counters describing one [`EventQueue`](super::EventQueue)'s
/// lifetime and current calendar geometry.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct QueueStats {
    /// Events currently queued.
    pub depth: usize,
    /// Most events ever queued at once (high-water mark).
    pub depth_hwm: usize,
    /// Lifetime number of events pushed.
    pub pushes: u64,
    /// Lifetime number of events popped.
    pub pops: u64,
    /// Buckets in the current calendar (a power of two).
    pub buckets: usize,
    /// Buckets currently holding at least one event.
    pub occupied_buckets: usize,
    /// Current bucket width in nanoseconds of virtual time.
    pub bucket_width_ns: u64,
    /// Geometry rebuilds performed (growth or width adaptation).
    pub resizes: u64,
    /// Empty calendar years answered by jumping to the minimum.
    pub sparse_jumps: u64,
}

impl QueueStats {
    /// Fraction of buckets holding at least one event, in `[0, 1]`.
    pub fn occupancy(&self) -> f64 {
        if self.buckets == 0 {
            0.0
        } else {
            self.occupied_buckets as f64 / self.buckets as f64
        }
    }

    /// One-line summary for reports and bench output.
    pub fn render(&self) -> String {
        format!(
            "events {}/{} (depth hwm {}), {}/{} buckets x {} ns, \
             {} resize(s), {} sparse jump(s)",
            self.pops,
            self.pushes,
            self.depth_hwm,
            self.occupied_buckets,
            self.buckets,
            self.bucket_width_ns,
            self.resizes,
            self.sparse_jumps,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn occupancy_is_a_fraction() {
        let s = QueueStats {
            buckets: 8,
            occupied_buckets: 2,
            ..QueueStats::default()
        };
        assert!((s.occupancy() - 0.25).abs() < 1e-12);
        assert_eq!(QueueStats::default().occupancy(), 0.0);
    }

    #[test]
    fn render_names_the_key_numbers() {
        let s = QueueStats {
            depth: 3,
            depth_hwm: 40,
            pushes: 100,
            pops: 97,
            buckets: 64,
            occupied_buckets: 3,
            bucket_width_ns: 250,
            resizes: 2,
            sparse_jumps: 1,
        };
        let text = s.render();
        assert!(text.contains("depth hwm 40"));
        assert!(text.contains("3/64 buckets"));
        assert!(text.contains("2 resize(s)"));
    }
}
