//! Scheduler observability counters.
//!
//! The calendar queue is the shared hot path of every scenario, so its
//! behaviour should be *observable*, not asserted: [`QueueStats`] is
//! the snapshot [`EventQueue::stats`](super::EventQueue::stats)
//! returns, surfaced through
//! [`FleetReport`](crate::container::FleetReport) (one queue per
//! deployment wave) and printed by the bench harness
//! (`benches/des_queue.rs`, `benches/fig1_scale.rs`).
//!
//! How to read a snapshot (docs/DES.md walks a full example):
//!
//! * `depth` / `depth_hwm` — pending events now / at the worst moment.
//!   The high-water mark bounds the memory the run needed and tells
//!   you how bursty the workload was.
//! * `buckets`, `occupied_buckets`, `bucket_width_ns` — the calendar
//!   geometry.  A healthy dense phase keeps occupancy
//!   (`occupied_buckets / buckets`) well under 1 with small widths;
//!   sparse phases widen the buckets instead of leaving the scan to
//!   walk empty days.
//! * `resizes` — geometry rebuilds (growth past the load factor, or
//!   width re-derivation after sparse jumps).  Each is O(depth); a hot
//!   loop resizing every few events means the spacing keeps shifting.
//! * `sparse_jumps` — full calendar years scanned without finding a
//!   due event, answered by jumping straight to the minimum.  Large
//!   counts mean the width is (or was) too narrow for the workload.
//! * `pushes` / `pops` — lifetime totals; `pushes - pops == depth`.

use super::time::Duration;

/// Counters describing one [`EventQueue`](super::EventQueue)'s
/// lifetime and current calendar geometry.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct QueueStats {
    /// Events currently queued.
    pub depth: usize,
    /// Most events ever queued at once (high-water mark).
    pub depth_hwm: usize,
    /// Lifetime number of events pushed.
    pub pushes: u64,
    /// Lifetime number of events popped.
    pub pops: u64,
    /// Buckets in the current calendar (a power of two).
    pub buckets: usize,
    /// Buckets currently holding at least one event.
    pub occupied_buckets: usize,
    /// Current bucket width in nanoseconds of virtual time.
    pub bucket_width_ns: u64,
    /// Geometry rebuilds performed (growth or width adaptation).
    pub resizes: u64,
    /// Empty calendar years answered by jumping to the minimum.
    pub sparse_jumps: u64,
}

impl QueueStats {
    /// Fraction of buckets holding at least one event, in `[0, 1]`.
    pub fn occupancy(&self) -> f64 {
        if self.buckets == 0 {
            0.0
        } else {
            self.occupied_buckets as f64 / self.buckets as f64
        }
    }

    /// One-line summary for reports and bench output.
    pub fn render(&self) -> String {
        format!(
            "events {}/{} (depth hwm {}), {}/{} buckets x {} ns, \
             {} resize(s), {} sparse jump(s)",
            self.pops,
            self.pushes,
            self.depth_hwm,
            self.occupied_buckets,
            self.buckets,
            self.bucket_width_ns,
            self.resizes,
            self.sparse_jumps,
        )
    }
}

/// Availability/MTTR accounting for one fault-injected run.
///
/// Two halves meet here: the *injected* side (crash/outage/storm
/// counts and node down-time, derived from the
/// [`FaultSchedule`](super::fault::FaultSchedule) windows) and the
/// *reaction* side (retries, failovers, dropped transfers, permanent
/// failures, counted by the distribution tier as it works around the
/// faults).  A fault-free run carries `FaultStats::default()` — every
/// counter zero — so reports stay bit-identical when no chaos is
/// configured.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct FaultStats {
    /// Node crashes injected.
    pub node_crashes: u64,
    /// Crashed nodes that rejoined (repairs completed).
    pub node_repairs: u64,
    /// Registry shard outages injected.
    pub shard_outages: u64,
    /// WAN drop windows injected.
    pub drop_windows: u64,
    /// Cache eviction storms injected.
    pub evict_storms: u64,
    /// WAN transfers lost to drop windows or timeouts.
    pub transfers_dropped: u64,
    /// Transfer re-attempts (WAN retries plus node re-deliveries).
    pub retries: u64,
    /// Pulls re-hashed to a surviving shard during an outage.
    pub failovers: u64,
    /// Nodes (or transfer targets) given up on for good.
    pub permanent_failures: u64,
    /// Summed node down-time overlapping the accounted span.
    pub downtime: Duration,
    /// Summed crash→rejoin spans of completed repairs.
    pub repair_time: Duration,
}

impl FaultStats {
    /// Mean time to repair: `repair_time / node_repairs`
    /// ([`Duration::ZERO`] when nothing was repaired).
    pub fn mttr(&self) -> Duration {
        if self.node_repairs == 0 {
            Duration::ZERO
        } else {
            Duration::from_secs_f64(self.repair_time.as_secs_f64() / self.node_repairs as f64)
        }
    }

    /// Fraction of node-seconds the fleet was up over `horizon`:
    /// `1 - downtime / (nodes × horizon)`, clamped to `[0, 1]`
    /// (`1.0` for an empty horizon).
    pub fn availability(&self, nodes: usize, horizon: Duration) -> f64 {
        let total = nodes as f64 * horizon.as_secs_f64();
        if total <= 0.0 {
            1.0
        } else {
            (1.0 - self.downtime.as_secs_f64() / total).clamp(0.0, 1.0)
        }
    }

    /// Accumulate another run's counters into this one (rolling
    /// deployments sum their ring reports).
    pub fn merge(&mut self, other: &FaultStats) {
        self.node_crashes += other.node_crashes;
        self.node_repairs += other.node_repairs;
        self.shard_outages += other.shard_outages;
        self.drop_windows += other.drop_windows;
        self.evict_storms += other.evict_storms;
        self.transfers_dropped += other.transfers_dropped;
        self.retries += other.retries;
        self.failovers += other.failovers;
        self.permanent_failures += other.permanent_failures;
        self.downtime += other.downtime;
        self.repair_time += other.repair_time;
    }

    /// One-line summary for reports and bench output.
    pub fn render(&self) -> String {
        format!(
            "faults: {} crash(es) ({} repaired, MTTR {}), {} outage(s), \
             {} drop window(s), {} storm(s); reaction: {} retry(ies), \
             {} failover(s), {} dropped, {} permanent failure(s)",
            self.node_crashes,
            self.node_repairs,
            self.mttr(),
            self.shard_outages,
            self.drop_windows,
            self.evict_storms,
            self.retries,
            self.failovers,
            self.transfers_dropped,
            self.permanent_failures,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn occupancy_is_a_fraction() {
        let s = QueueStats {
            buckets: 8,
            occupied_buckets: 2,
            ..QueueStats::default()
        };
        assert!((s.occupancy() - 0.25).abs() < 1e-12);
        assert_eq!(QueueStats::default().occupancy(), 0.0);
    }

    #[test]
    fn render_names_the_key_numbers() {
        let s = QueueStats {
            depth: 3,
            depth_hwm: 40,
            pushes: 100,
            pops: 97,
            buckets: 64,
            occupied_buckets: 3,
            bucket_width_ns: 250,
            resizes: 2,
            sparse_jumps: 1,
        };
        let text = s.render();
        assert!(text.contains("depth hwm 40"));
        assert!(text.contains("3/64 buckets"));
        assert!(text.contains("2 resize(s)"));
    }

    #[test]
    fn fault_stats_mttr_and_availability() {
        let mut f = FaultStats::default();
        assert_eq!(f.mttr(), Duration::ZERO);
        assert_eq!(f.availability(16, Duration::from_millis(100)), 1.0);
        f.node_repairs = 2;
        f.repair_time = Duration::from_millis(30);
        f.downtime = Duration::from_millis(40);
        assert_eq!(f.mttr(), Duration::from_millis(15));
        // 40 ms down over 4 nodes x 100 ms = 90% available
        let a = f.availability(4, Duration::from_millis(100));
        assert!((a - 0.9).abs() < 1e-12, "{a}");
        assert_eq!(FaultStats::default().availability(0, Duration::ZERO), 1.0);
    }

    #[test]
    fn fault_stats_merge_and_render() {
        let mut a = FaultStats {
            node_crashes: 1,
            retries: 2,
            ..FaultStats::default()
        };
        let b = FaultStats {
            node_crashes: 2,
            failovers: 1,
            downtime: Duration::from_millis(5),
            ..FaultStats::default()
        };
        a.merge(&b);
        assert_eq!(a.node_crashes, 3);
        assert_eq!(a.retries, 2);
        assert_eq!(a.failovers, 1);
        assert_eq!(a.downtime, Duration::from_millis(5));
        let text = a.render();
        assert!(text.contains("3 crash(es)"));
        assert!(text.contains("2 retry(ies)"));
        assert!(text.contains("1 failover(s)"));
    }
}
