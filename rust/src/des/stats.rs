//! Scheduler observability counters.
//!
//! The calendar queue is the shared hot path of every scenario, so its
//! behaviour should be *observable*, not asserted: [`QueueStats`] is
//! the snapshot [`EventQueue::stats`](super::EventQueue::stats)
//! returns, surfaced through
//! [`FleetReport`](crate::container::FleetReport) (one queue per
//! deployment wave) and printed by the bench harness
//! (`benches/des_queue.rs`, `benches/fig1_scale.rs`).
//!
//! How to read a snapshot (docs/DES.md walks a full example):
//!
//! * `depth` / `depth_hwm` — pending events now / at the worst moment.
//!   The high-water mark bounds the memory the run needed and tells
//!   you how bursty the workload was.
//! * `buckets`, `occupied_buckets`, `bucket_width_ns` — the calendar
//!   geometry.  A healthy dense phase keeps occupancy
//!   (`occupied_buckets / buckets`) well under 1 with small widths;
//!   sparse phases widen the buckets instead of leaving the scan to
//!   walk empty days.
//! * `resizes` — geometry rebuilds (growth past the load factor, or
//!   width re-derivation after sparse jumps).  Each is O(depth); a hot
//!   loop resizing every few events means the spacing keeps shifting.
//! * `sparse_jumps` — full calendar years scanned without finding a
//!   due event, answered by jumping straight to the minimum.  Large
//!   counts mean the width is (or was) too narrow for the workload.
//! * `pushes` / `pops` — lifetime totals; `pushes - pops == depth`.
//!
//! Latency observability lives here too: [`LatencyHistogram`] is a
//! deterministic streaming percentile estimator (fixed log-spaced
//! integer bins, so p50/p99/p999 are exactly reproducible across
//! machines and `--jobs` settings), and [`warmup_trim`] /
//! [`is_stationary`] are the transient-removal helpers open-loop
//! scenarios apply before reporting steady-state percentiles.

use super::time::Duration;

/// Significant mantissa bits per histogram octave (`2^SUB_BITS`
/// linear sub-bins per power of two).
const SUB_BITS: u32 = 5;
/// Sub-bins per octave; also the number of exact unit-width low bins.
const SUB: usize = 1 << SUB_BITS;
/// Total bins: `SUB` exact low bins plus `SUB` sub-bins for every
/// octave `SUB_BITS..=63`, covering the full `u64` nanosecond range.
const BINS: usize = SUB * (64 - SUB_BITS as usize + 1);

/// The bin a `ns` value lands in.  Values below `SUB` get exact
/// unit-width bins; above, the top `SUB_BITS + 1` mantissa bits pick
/// an octave and a linear sub-bin within it — integer-only (no libm),
/// so the mapping is bit-identical on every platform.
fn bin_of(ns: u64) -> usize {
    if ns < SUB as u64 {
        return ns as usize;
    }
    let e = 63 - ns.leading_zeros();
    let shift = e - SUB_BITS;
    let sub = (ns >> shift) as usize - SUB;
    SUB + shift as usize * SUB + sub
}

/// Largest `ns` value mapping to `bin` (the estimator quotes this
/// upper edge, so estimates never under-report a quantile).
fn bin_max(bin: usize) -> u64 {
    if bin < SUB {
        return bin as u64;
    }
    let shift = ((bin - SUB) / SUB) as u32;
    let sub = ((bin - SUB) % SUB) as u64;
    let lo = (SUB as u64 + sub) << shift;
    lo + (1u64 << shift) - 1
}

/// Deterministic streaming percentile estimator over `Duration`
/// samples.
///
/// Samples are counted into fixed log-spaced integer bins (HDR-style:
/// `SUB_BITS` significant bits, so every bin spans at most `1/32` of
/// its lower edge).  Quantiles quote the upper edge of the bin holding
/// the requested rank, clamped to the exact observed maximum, which
/// bounds the relative over-estimate by `1/32` and never
/// under-reports.  Because the bins are fixed and integer-indexed, the
/// same sample stream yields bit-identical `p50/p99/p999` on every
/// machine and at every `--jobs` setting — the property the scenario
/// determinism gates rely on.  `min`/`max`/`mean` are tracked exactly.
#[derive(Clone, PartialEq, Eq)]
pub struct LatencyHistogram {
    /// Sample count per bin (`BINS` entries).
    counts: Vec<u64>,
    /// Total samples recorded.
    count: u64,
    /// Exact sum of all recorded nanoseconds.
    total_ns: u128,
    /// Exact minimum recorded, in nanoseconds.
    min_ns: u64,
    /// Exact maximum recorded, in nanoseconds.
    max_ns: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram::new()
    }
}

impl std::fmt::Debug for LatencyHistogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("LatencyHistogram")
            .field("count", &self.count)
            .field("mean", &self.mean())
            .field("p50", &self.p50())
            .field("p99", &self.p99())
            .field("max", &self.max())
            .finish()
    }
}

impl LatencyHistogram {
    /// An empty histogram.
    pub fn new() -> Self {
        LatencyHistogram {
            counts: vec![0; BINS],
            count: 0,
            total_ns: 0,
            min_ns: u64::MAX,
            max_ns: 0,
        }
    }

    /// Record one latency sample.
    pub fn record(&mut self, sample: Duration) {
        let ns = sample.as_nanos();
        self.counts[bin_of(ns)] += 1;
        self.count += 1;
        self.total_ns += u128::from(ns);
        self.min_ns = self.min_ns.min(ns);
        self.max_ns = self.max_ns.max(ns);
    }

    /// Number of samples recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Whether no samples have been recorded.
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Exact minimum sample ([`Duration::ZERO`] when empty).
    pub fn min(&self) -> Duration {
        if self.count == 0 {
            Duration::ZERO
        } else {
            Duration::from_nanos(self.min_ns)
        }
    }

    /// Exact maximum sample ([`Duration::ZERO`] when empty).
    pub fn max(&self) -> Duration {
        Duration::from_nanos(self.max_ns)
    }

    /// Exact mean sample (integer nanoseconds; [`Duration::ZERO`] when
    /// empty).
    pub fn mean(&self) -> Duration {
        if self.count == 0 {
            Duration::ZERO
        } else {
            Duration::from_nanos((self.total_ns / u128::from(self.count)) as u64)
        }
    }

    /// The `q`-quantile estimate: the upper edge of the bin holding
    /// rank `ceil(q * count)` (clamped to `[1, count]`), itself
    /// clamped to the exact observed maximum.  Guarantees
    /// `exact <= quantile(q) <= exact * (1 + 1/32)`.  Empty histogram
    /// ⇒ [`Duration::ZERO`]; `q <= 0` ⇒ the exact minimum.
    pub fn quantile(&self, q: f64) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        if q <= 0.0 {
            return Duration::from_nanos(self.min_ns);
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (bin, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return Duration::from_nanos(bin_max(bin).min(self.max_ns));
            }
        }
        Duration::from_nanos(self.max_ns)
    }

    /// Median estimate (`quantile(0.5)`).
    pub fn p50(&self) -> Duration {
        self.quantile(0.5)
    }

    /// 99th-percentile estimate (`quantile(0.99)`).
    pub fn p99(&self) -> Duration {
        self.quantile(0.99)
    }

    /// 99.9th-percentile estimate (`quantile(0.999)`).
    pub fn p999(&self) -> Duration {
        self.quantile(0.999)
    }

    /// Accumulate another histogram's samples into this one (shard or
    /// per-worker histograms merge losslessly: binning is fixed, so
    /// merge-then-quantile equals record-everything-then-quantile).
    pub fn merge(&mut self, other: &LatencyHistogram) {
        if other.count == 0 {
            return;
        }
        for (c, &o) in self.counts.iter_mut().zip(&other.counts) {
            *c += o;
        }
        self.count += other.count;
        self.total_ns += other.total_ns;
        self.min_ns = self.min_ns.min(other.min_ns);
        self.max_ns = self.max_ns.max(other.max_ns);
    }

    /// One-line summary for reports and bench output.
    pub fn render(&self) -> String {
        if self.count == 0 {
            return "latency: no samples".to_string();
        }
        format!(
            "latency: n {}, mean {}, p50 {}, p99 {}, p999 {}, max {}",
            self.count,
            self.mean(),
            self.p50(),
            self.p99(),
            self.p999(),
            self.max(),
        )
    }
}

/// Number of leading samples to discard as initialisation transient,
/// by the MSER rule: pick the truncation point `d` (at most `n/2`)
/// minimising the marginal standard error
/// `variance(samples[d..]) / (n - d)` of the remaining mean.  Series
/// shorter than 8 samples are returned untrimmed.  Pure function of
/// the sample values — deterministic across machines.
pub fn warmup_trim(samples: &[f64]) -> usize {
    let n = samples.len();
    if n < 8 {
        return 0;
    }
    // suffix sums so every candidate truncation is O(1)
    let mut s1 = vec![0.0; n + 1];
    let mut s2 = vec![0.0; n + 1];
    for i in (0..n).rev() {
        s1[i] = s1[i + 1] + samples[i];
        s2[i] = s2[i + 1] + samples[i] * samples[i];
    }
    let mut best_d = 0;
    let mut best = f64::INFINITY;
    for d in 0..=n / 2 {
        let m = (n - d) as f64;
        let var = (s2[d] - s1[d] * s1[d] / m).max(0.0);
        let stat = var / (m * m);
        if stat < best {
            best = stat;
            best_d = d;
        }
    }
    best_d
}

/// Whether a (warmup-trimmed) series looks steady-state: the means of
/// its first and second halves differ by at most `tol` relative to the
/// larger of the two.  Series shorter than 2 samples are trivially
/// stationary.
pub fn is_stationary(samples: &[f64], tol: f64) -> bool {
    let n = samples.len();
    if n < 2 {
        return true;
    }
    let half = n / 2;
    let m1 = samples[..half].iter().sum::<f64>() / half as f64;
    let m2 = samples[n - half..].iter().sum::<f64>() / half as f64;
    let scale = m1.abs().max(m2.abs());
    if scale <= f64::EPSILON {
        return true;
    }
    (m1 - m2).abs() / scale <= tol
}

/// Counters describing one [`EventQueue`](super::EventQueue)'s
/// lifetime and current calendar geometry.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct QueueStats {
    /// Events currently queued.
    pub depth: usize,
    /// Most events ever queued at once (high-water mark).
    pub depth_hwm: usize,
    /// Lifetime number of events pushed.
    pub pushes: u64,
    /// Lifetime number of events popped.
    pub pops: u64,
    /// Buckets in the current calendar (a power of two).
    pub buckets: usize,
    /// Buckets currently holding at least one event.
    pub occupied_buckets: usize,
    /// Current bucket width in nanoseconds of virtual time.
    pub bucket_width_ns: u64,
    /// Geometry rebuilds performed (growth or width adaptation).
    pub resizes: u64,
    /// Empty calendar years answered by jumping to the minimum.
    pub sparse_jumps: u64,
}

impl QueueStats {
    /// Fraction of buckets holding at least one event, in `[0, 1]`.
    pub fn occupancy(&self) -> f64 {
        if self.buckets == 0 {
            0.0
        } else {
            self.occupied_buckets as f64 / self.buckets as f64
        }
    }

    /// One-line summary for reports and bench output.
    pub fn render(&self) -> String {
        format!(
            "events {}/{} (depth hwm {}), {}/{} buckets x {} ns, \
             {} resize(s), {} sparse jump(s)",
            self.pops,
            self.pushes,
            self.depth_hwm,
            self.occupied_buckets,
            self.buckets,
            self.bucket_width_ns,
            self.resizes,
            self.sparse_jumps,
        )
    }
}

/// Availability/MTTR accounting for one fault-injected run.
///
/// Two halves meet here: the *injected* side (crash/outage/storm
/// counts and node down-time, derived from the
/// [`FaultSchedule`](super::fault::FaultSchedule) windows) and the
/// *reaction* side (retries, failovers, dropped transfers, permanent
/// failures, counted by the distribution tier as it works around the
/// faults).  A fault-free run carries `FaultStats::default()` — every
/// counter zero — so reports stay bit-identical when no chaos is
/// configured.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct FaultStats {
    /// Node crashes injected.
    pub node_crashes: u64,
    /// Crashed nodes that rejoined (repairs completed).
    pub node_repairs: u64,
    /// Registry shard outages injected.
    pub shard_outages: u64,
    /// WAN drop windows injected.
    pub drop_windows: u64,
    /// Cache eviction storms injected.
    pub evict_storms: u64,
    /// WAN transfers lost to drop windows or timeouts.
    pub transfers_dropped: u64,
    /// Transfer re-attempts (WAN retries plus node re-deliveries).
    pub retries: u64,
    /// Pulls re-hashed to a surviving shard during an outage.
    pub failovers: u64,
    /// Nodes (or transfer targets) given up on for good.
    pub permanent_failures: u64,
    /// Summed node down-time overlapping the accounted span.
    pub downtime: Duration,
    /// Summed crash→rejoin spans of completed repairs.
    pub repair_time: Duration,
}

impl FaultStats {
    /// Mean time to repair: `repair_time / node_repairs`
    /// ([`Duration::ZERO`] when nothing was repaired).
    pub fn mttr(&self) -> Duration {
        if self.node_repairs == 0 {
            Duration::ZERO
        } else {
            Duration::from_secs_f64(self.repair_time.as_secs_f64() / self.node_repairs as f64)
        }
    }

    /// Fraction of node-seconds the fleet was up over `horizon`:
    /// `1 - downtime / (nodes × horizon)`, clamped to `[0, 1]`
    /// (`1.0` for an empty horizon).
    pub fn availability(&self, nodes: usize, horizon: Duration) -> f64 {
        let total = nodes as f64 * horizon.as_secs_f64();
        if total <= 0.0 {
            1.0
        } else {
            (1.0 - self.downtime.as_secs_f64() / total).clamp(0.0, 1.0)
        }
    }

    /// Accumulate another run's counters into this one (rolling
    /// deployments sum their ring reports).
    pub fn merge(&mut self, other: &FaultStats) {
        self.node_crashes += other.node_crashes;
        self.node_repairs += other.node_repairs;
        self.shard_outages += other.shard_outages;
        self.drop_windows += other.drop_windows;
        self.evict_storms += other.evict_storms;
        self.transfers_dropped += other.transfers_dropped;
        self.retries += other.retries;
        self.failovers += other.failovers;
        self.permanent_failures += other.permanent_failures;
        self.downtime += other.downtime;
        self.repair_time += other.repair_time;
    }

    /// One-line summary for reports and bench output.
    pub fn render(&self) -> String {
        format!(
            "faults: {} crash(es) ({} repaired, MTTR {}), {} outage(s), \
             {} drop window(s), {} storm(s); reaction: {} retry(ies), \
             {} failover(s), {} dropped, {} permanent failure(s)",
            self.node_crashes,
            self.node_repairs,
            self.mttr(),
            self.shard_outages,
            self.drop_windows,
            self.evict_storms,
            self.retries,
            self.failovers,
            self.transfers_dropped,
            self.permanent_failures,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn occupancy_is_a_fraction() {
        let s = QueueStats {
            buckets: 8,
            occupied_buckets: 2,
            ..QueueStats::default()
        };
        assert!((s.occupancy() - 0.25).abs() < 1e-12);
        assert_eq!(QueueStats::default().occupancy(), 0.0);
    }

    #[test]
    fn render_names_the_key_numbers() {
        let s = QueueStats {
            depth: 3,
            depth_hwm: 40,
            pushes: 100,
            pops: 97,
            buckets: 64,
            occupied_buckets: 3,
            bucket_width_ns: 250,
            resizes: 2,
            sparse_jumps: 1,
        };
        let text = s.render();
        assert!(text.contains("depth hwm 40"));
        assert!(text.contains("3/64 buckets"));
        assert!(text.contains("2 resize(s)"));
    }

    #[test]
    fn fault_stats_mttr_and_availability() {
        let mut f = FaultStats::default();
        assert_eq!(f.mttr(), Duration::ZERO);
        assert_eq!(f.availability(16, Duration::from_millis(100)), 1.0);
        f.node_repairs = 2;
        f.repair_time = Duration::from_millis(30);
        f.downtime = Duration::from_millis(40);
        assert_eq!(f.mttr(), Duration::from_millis(15));
        // 40 ms down over 4 nodes x 100 ms = 90% available
        let a = f.availability(4, Duration::from_millis(100));
        assert!((a - 0.9).abs() < 1e-12, "{a}");
        assert_eq!(FaultStats::default().availability(0, Duration::ZERO), 1.0);
    }

    #[test]
    fn fault_stats_merge_and_render() {
        let mut a = FaultStats {
            node_crashes: 1,
            retries: 2,
            ..FaultStats::default()
        };
        let b = FaultStats {
            node_crashes: 2,
            failovers: 1,
            downtime: Duration::from_millis(5),
            ..FaultStats::default()
        };
        a.merge(&b);
        assert_eq!(a.node_crashes, 3);
        assert_eq!(a.retries, 2);
        assert_eq!(a.failovers, 1);
        assert_eq!(a.downtime, Duration::from_millis(5));
        let text = a.render();
        assert!(text.contains("3 crash(es)"));
        assert!(text.contains("2 retry(ies)"));
        assert!(text.contains("1 failover(s)"));
    }

    use crate::des::rng::SimRng;

    /// Every quantile estimate must sit in `[exact, exact * 33/32]`
    /// against the exact sorted-sample oracle.
    fn oracle_check(name: &str, samples: &[u64]) {
        let mut h = LatencyHistogram::new();
        for &s in samples {
            h.record(Duration::from_nanos(s));
        }
        let mut sorted = samples.to_vec();
        sorted.sort_unstable();
        for &q in &[0.01, 0.25, 0.5, 0.9, 0.99, 0.999, 1.0] {
            let rank = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
            let exact = sorted[rank - 1];
            let est = h.quantile(q).as_nanos();
            assert!(est >= exact, "{name} q={q}: est {est} < exact {exact}");
            assert!(
                est <= exact + exact / 32 + 1,
                "{name} q={q}: est {est} beyond bin width of exact {exact}"
            );
        }
        assert_eq!(h.count(), samples.len() as u64);
        assert_eq!(h.min().as_nanos(), sorted[0]);
        assert_eq!(h.max().as_nanos(), *sorted.last().unwrap());
        let exact_mean = samples.iter().map(|&s| u128::from(s)).sum::<u128>()
            / samples.len() as u128;
        assert_eq!(h.mean().as_nanos(), exact_mean as u64, "{name} mean is exact");
    }

    #[test]
    fn histogram_matches_oracle_on_uniform_stream() {
        let mut rng = SimRng::new(1, "hist-uniform");
        let samples: Vec<u64> = (0..10_000).map(|_| rng.uniform(1e3, 1e8) as u64).collect();
        oracle_check("uniform", &samples);
    }

    #[test]
    fn histogram_matches_oracle_on_bimodal_stream() {
        let mut rng = SimRng::new(2, "hist-bimodal");
        let samples: Vec<u64> = (0..10_000)
            .map(|_| {
                if rng.uniform(0.0, 1.0) < 0.8 {
                    rng.uniform(0.8e6, 1.2e6) as u64 // ~1 ms mode
                } else {
                    rng.uniform(0.8e8, 1.2e8) as u64 // ~100 ms mode
                }
            })
            .collect();
        oracle_check("bimodal", &samples);
    }

    #[test]
    fn histogram_matches_oracle_on_pareto_tail() {
        let mut rng = SimRng::new(3, "hist-pareto");
        let samples: Vec<u64> = (0..10_000)
            .map(|_| {
                let u: f64 = rng.uniform(0.0, 1.0);
                ((1e4 / (1.0 - u).powf(1.0 / 1.5)) as u64).min(1_000_000_000_000)
            })
            .collect();
        oracle_check("pareto", &samples);
    }

    #[test]
    fn histogram_empty_and_one_sample_edges() {
        let h = LatencyHistogram::new();
        assert!(h.is_empty());
        assert_eq!(h.count(), 0);
        assert_eq!(h.p50(), Duration::ZERO);
        assert_eq!(h.p999(), Duration::ZERO);
        assert_eq!(h.mean(), Duration::ZERO);
        assert_eq!(h.min(), Duration::ZERO);
        assert_eq!(h.render(), "latency: no samples");

        let mut h = LatencyHistogram::new();
        h.record(Duration::from_millis(7));
        for q in [0.0, 0.5, 0.99, 0.999, 1.0] {
            assert_eq!(h.quantile(q), Duration::from_millis(7), "q={q}");
        }
        assert_eq!(h.mean(), Duration::from_millis(7));
        assert_eq!(h.min(), h.max());
        assert!(h.render().contains("n 1"));
    }

    #[test]
    fn histogram_zero_sample_is_representable() {
        let mut h = LatencyHistogram::new();
        h.record(Duration::ZERO);
        h.record(Duration::from_nanos(1));
        assert_eq!(h.quantile(0.0), Duration::ZERO);
        assert_eq!(h.quantile(1.0), Duration::from_nanos(1));
    }

    #[test]
    fn histogram_bins_round_trip() {
        let mut probes = vec![0u64, 1, 31, 32, 33, 63, 64, 65, 1_000, u64::MAX];
        let mut rng = SimRng::new(4, "hist-bins");
        for _ in 0..1_000 {
            probes.push(rng.uniform(0.0, 1e18) as u64);
        }
        for &ns in &probes {
            let bin = bin_of(ns);
            assert!(bin < BINS, "{ns} -> bin {bin}");
            let hi = bin_max(bin);
            assert!(hi >= ns, "{ns}: bin max {hi} below the sample");
            assert_eq!(bin_of(hi), bin, "{ns}: bin max maps back to the bin");
            assert!(hi - ns <= ns / 32 + 1, "{ns}: bin wider than 1/32");
        }
    }

    #[test]
    fn histogram_merge_equals_recording_everything() {
        let mut rng = SimRng::new(5, "hist-merge");
        let a: Vec<u64> = (0..500).map(|_| rng.uniform(1e3, 1e9) as u64).collect();
        let b: Vec<u64> = (0..700).map(|_| rng.uniform(1e2, 1e7) as u64).collect();
        let mut ha = LatencyHistogram::new();
        let mut hb = LatencyHistogram::new();
        let mut all = LatencyHistogram::new();
        for &s in &a {
            ha.record(Duration::from_nanos(s));
            all.record(Duration::from_nanos(s));
        }
        for &s in &b {
            hb.record(Duration::from_nanos(s));
            all.record(Duration::from_nanos(s));
        }
        ha.merge(&hb);
        assert_eq!(ha, all, "merge is lossless");
        let empty = LatencyHistogram::new();
        let snapshot = ha.clone();
        ha.merge(&empty);
        assert_eq!(ha, snapshot, "merging empty is a no-op");
    }

    #[test]
    fn warmup_trim_finds_the_transient() {
        let mut series = vec![10.0; 20];
        series.extend(vec![1.0; 80]);
        assert_eq!(warmup_trim(&series), 20);
        assert_eq!(warmup_trim(&[5.0; 100]), 0, "steady series untrimmed");
        assert_eq!(warmup_trim(&[1.0, 2.0, 3.0]), 0, "short series untrimmed");
        assert_eq!(warmup_trim(&[]), 0);
    }

    #[test]
    fn stationarity_detects_drift() {
        let flat: Vec<f64> = (0..100).map(|i| 5.0 + 0.001 * (i % 3) as f64).collect();
        assert!(is_stationary(&flat, 0.05));
        let ramp: Vec<f64> = (0..100).map(|i| i as f64).collect();
        assert!(!is_stationary(&ramp, 0.05));
        assert!(is_stationary(&[], 0.0), "empty is trivially stationary");
        assert!(is_stationary(&[0.0, 0.0], 0.0), "all-zero is stationary");
    }
}
