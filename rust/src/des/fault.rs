//! Deterministic fault injection for the DES.
//!
//! Every fault a chaos scenario injects is drawn **once**, up front,
//! from a [`SimRng`] stream into a [`FaultSchedule`]: a time-sorted
//! list of typed [`Fault`] events that can be replayed through the
//! calendar [`EventQueue`] like any other event source.  Because the
//! schedule is a pure function of `(seed, FaultConfig)`, a chaos run is
//! exactly as reproducible as a fault-free one — the `(time, seq)`
//! golden contract of the queue is untouched, and the same seed yields
//! the same crashes, outages, and drop windows on every machine and at
//! every `--jobs` setting.
//!
//! The schedule exposes two complementary views:
//!
//! * **Event view** — [`FaultSchedule::events`] /
//!   [`FaultSchedule::replay`]: the raw injections in calendar order,
//!   for driving an event loop or auditing a run.
//! * **Window view** — [`FaultSchedule::node_down_at`],
//!   [`FaultSchedule::shard_next_up`], [`FaultSchedule::drop_until`]:
//!   crash/rejoin and outage/recover pairs folded into down-time
//!   intervals, which is what the distribution tier consults when it
//!   decides whether a delivery lands or a WAN attempt must retry
//!   (see `container::distribute`).
//!
//! Availability/MTTR accounting lives in
//! [`FaultStats`](super::stats::FaultStats); a deployment merges the
//! schedule-derived part ([`FaultSchedule::stats_over`]) with its own
//! retry/failover counters.

use super::queue::EventQueue;
use super::rng::SimRng;
use super::stats::{FaultStats, QueueStats};
use super::time::{Duration, VirtualTime};

/// One typed fault injection.
///
/// Crash/rejoin and outage/recover events come in pairs (a crash with
/// no matching rejoin is a permanent failure); drop windows and evict
/// storms are self-contained.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fault {
    /// A compute node dies.  Deliveries that arrive while it is down
    /// are lost (the bytes count as wasted traffic); its cache
    /// contents survive the crash.
    NodeCrash {
        /// Index of the crashed node.
        node: usize,
    },
    /// A crashed node comes back and can receive and serve layers
    /// again.
    NodeRejoin {
        /// Index of the rejoining node.
        node: usize,
    },
    /// A registry shard frontend goes dark; pulls re-hash to the
    /// surviving shards (failover) until it recovers.
    ShardOutage {
        /// Index of the failed shard.
        shard: usize,
    },
    /// A failed shard frontend comes back.
    ShardRecover {
        /// Index of the recovering shard.
        shard: usize,
    },
    /// WAN transfers *started* while the window is open are lost and
    /// must be retried.
    TransferDrop {
        /// Instant the drop window closes.
        until: VirtualTime,
    },
    /// Cache pressure evicts up to `bytes` of least-recently-used
    /// layers from one node's cache.
    CacheEvictStorm {
        /// Index of the pressured node.
        node: usize,
        /// Bytes of resident layers to shed.
        bytes: u64,
    },
}

/// Parameters of one generated fault schedule.
///
/// `intensity` is the single chaos dial: `0.0` produces an **empty**
/// schedule (bit-identical to a fault-free run by construction);
/// higher values scale the number of crashes, outages, drop windows,
/// and evict storms injected over the `horizon`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultConfig {
    /// Fleet size the node-targeting faults draw indices from.
    pub nodes: usize,
    /// Registry shard count the outage faults draw indices from.
    pub shards: usize,
    /// Window of virtual time (from the schedule's origin) faults are
    /// scheduled within.
    pub horizon: Duration,
    /// Chaos dial in `[0, 1]`-ish: `0.0` = no faults; `1.0` ≈ 1 % of
    /// nodes crash, every shard sees an outage, three drop windows.
    pub intensity: f64,
    /// Mean repair time for crashes and outages (scaled ±50 % per
    /// fault) and mean width of drop windows.
    pub mean_downtime: Duration,
    /// Mean bytes an eviction storm sheds from a node cache.
    pub storm_bytes: u64,
}

impl FaultConfig {
    /// A schedule config with the default repair time (5 s virtual)
    /// and storm size (256 MB).
    pub fn new(nodes: usize, shards: usize, horizon: Duration, intensity: f64) -> Self {
        FaultConfig {
            nodes,
            shards,
            horizon,
            intensity,
            mean_downtime: Duration::from_secs_f64(5.0),
            storm_bytes: 256_000_000,
        }
    }

    /// The same config at a different intensity (builder-style).
    pub fn with_intensity(mut self, intensity: f64) -> Self {
        self.intensity = intensity;
        self
    }
}

/// `ceil(intensity * base)` fault instances; zero intensity injects
/// nothing at all.
fn count(intensity: f64, base: f64) -> usize {
    if intensity <= 0.0 {
        0
    } else {
        (intensity * base).ceil() as usize
    }
}

/// Whether a `[from, up)` down window covers instant `t` (`up = None`
/// never closes).
fn covers(from: VirtualTime, up: Option<VirtualTime>, t: VirtualTime) -> bool {
    from <= t
        && match up {
            None => true,
            Some(u) => t < u,
        }
}

/// A deterministic, time-sorted schedule of typed fault injections,
/// plus the down-time window views derived from it.
#[derive(Debug, Clone, Default)]
pub struct FaultSchedule {
    /// Injections sorted by time (FIFO within a tie, insertion order).
    events: Vec<(VirtualTime, Fault)>,
    /// Per-node down windows: `(node, down_from, up_at)`; `None` means
    /// the node never rejoins (permanent failure).
    node_windows: Vec<(usize, VirtualTime, Option<VirtualTime>)>,
    /// Per-shard outage windows, same shape as `node_windows`.
    shard_windows: Vec<(usize, VirtualTime, Option<VirtualTime>)>,
    /// WAN drop windows `(open, close)`.
    drop_windows: Vec<(VirtualTime, VirtualTime)>,
    /// Evict storms `(at, node, bytes)`.
    storms: Vec<(VirtualTime, usize, u64)>,
}

impl FaultSchedule {
    /// The empty schedule — a fault-free run.
    pub fn none() -> Self {
        FaultSchedule::default()
    }

    /// Build a schedule from explicit events (tests, hand-written
    /// chaos cases).  Events are stably sorted by time and folded into
    /// window views: each `NodeCrash` pairs with the next `NodeRejoin`
    /// for the same node (likewise shards); a crash with no rejoin is
    /// a permanent failure.
    pub fn from_events(mut events: Vec<(VirtualTime, Fault)>) -> Self {
        events.sort_by_key(|&(t, _)| t);
        let mut node_windows: Vec<(usize, VirtualTime, Option<VirtualTime>)> = Vec::new();
        let mut shard_windows: Vec<(usize, VirtualTime, Option<VirtualTime>)> = Vec::new();
        let mut drop_windows = Vec::new();
        let mut storms = Vec::new();
        for &(t, fault) in &events {
            match fault {
                Fault::NodeCrash { node } => node_windows.push((node, t, None)),
                Fault::NodeRejoin { node } => {
                    if let Some(w) = node_windows
                        .iter_mut()
                        .rev()
                        .find(|w| w.0 == node && w.2.is_none())
                    {
                        w.2 = Some(t);
                    }
                }
                Fault::ShardOutage { shard } => shard_windows.push((shard, t, None)),
                Fault::ShardRecover { shard } => {
                    if let Some(w) = shard_windows
                        .iter_mut()
                        .rev()
                        .find(|w| w.0 == shard && w.2.is_none())
                    {
                        w.2 = Some(t);
                    }
                }
                Fault::TransferDrop { until } => drop_windows.push((t, until)),
                Fault::CacheEvictStorm { node, bytes } => storms.push((t, node, bytes)),
            }
        }
        FaultSchedule {
            events,
            node_windows,
            shard_windows,
            drop_windows,
            storms,
        }
    }

    /// Generate a schedule deterministically from an RNG stream.  The
    /// draw order is fixed (crashes, then outages, then drop windows,
    /// then storms), so the same `(seed, config)` always yields the
    /// same schedule; zero intensity yields the empty schedule.
    ///
    /// About 90 % of crashes are repaired after
    /// `mean_downtime × U(0.5, 1.5)`; the rest never rejoin
    /// (permanent node failures).  Shard outages always recover.
    pub fn generate(cfg: &FaultConfig, rng: &mut SimRng) -> Self {
        let mut events = Vec::new();
        let horizon_ns = cfg.horizon.as_nanos() as f64;
        let at = |rng: &mut SimRng| VirtualTime(rng.uniform(0.0, horizon_ns.max(1.0)) as u64);

        for _ in 0..count(cfg.intensity, cfg.nodes as f64 * 0.01) {
            let node = rng.index(cfg.nodes.max(1));
            let t = at(rng);
            let repaired = rng.uniform(0.0, 1.0) < 0.9;
            events.push((t, Fault::NodeCrash { node }));
            if repaired {
                let down = cfg.mean_downtime.scale(rng.uniform(0.5, 1.5));
                events.push((t + down, Fault::NodeRejoin { node }));
            }
        }
        for _ in 0..count(cfg.intensity, cfg.shards as f64) {
            let shard = rng.index(cfg.shards.max(1));
            let t = at(rng);
            let down = cfg.mean_downtime.scale(rng.uniform(0.5, 1.5));
            events.push((t, Fault::ShardOutage { shard }));
            events.push((t + down, Fault::ShardRecover { shard }));
        }
        for _ in 0..count(cfg.intensity, 3.0) {
            let t = at(rng);
            let width = cfg.mean_downtime.scale(rng.uniform(0.5, 1.5));
            events.push((t, Fault::TransferDrop { until: t + width }));
        }
        for _ in 0..count(cfg.intensity, cfg.nodes as f64 * 0.002) {
            let node = rng.index(cfg.nodes.max(1));
            let t = at(rng);
            let bytes = (cfg.storm_bytes as f64 * rng.uniform(0.5, 1.5)) as u64;
            events.push((t, Fault::CacheEvictStorm { node, bytes }));
        }
        Self::from_events(events)
    }

    /// The same schedule shifted so its origin is `start` (schedules
    /// are generated relative to `VirtualTime::ZERO`; a deployment
    /// starting mid-simulation shifts them onto its own clock).
    pub fn shifted(&self, start: VirtualTime) -> Self {
        let shift = |t: VirtualTime| VirtualTime(start.0 + t.0);
        Self::from_events(
            self.events
                .iter()
                .map(|&(t, fault)| {
                    let fault = match fault {
                        Fault::TransferDrop { until } => Fault::TransferDrop {
                            until: shift(until),
                        },
                        other => other,
                    };
                    (shift(t), fault)
                })
                .collect(),
        )
    }

    /// The injections, sorted by time.
    pub fn events(&self) -> &[(VirtualTime, Fault)] {
        &self.events
    }

    /// Number of injections.
    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// Whether the schedule injects nothing (a fault-free run).
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Whether `node` is down at instant `t`.
    pub fn node_down_at(&self, node: usize, t: VirtualTime) -> bool {
        self.node_windows
            .iter()
            .any(|&(n, from, up)| n == node && covers(from, up, t))
    }

    /// Earliest instant `>= t` at which `node` is up: `Some(t)` if it
    /// is up now, the end of its current (and any immediately
    /// following) down window otherwise, `None` if it never rejoins.
    pub fn node_next_up(&self, node: usize, t: VirtualTime) -> Option<VirtualTime> {
        let mut t = t;
        loop {
            let down = self
                .node_windows
                .iter()
                .filter(|&&(n, from, up)| n == node && covers(from, up, t))
                .map(|&(_, _, up)| up)
                .collect::<Vec<_>>();
            if down.is_empty() {
                return Some(t);
            }
            // inside one or more windows: advance past the latest one
            // (a window with no rejoin means down forever)
            let mut next = t;
            for up in down {
                match up {
                    None => return None,
                    Some(u) => next = next.max(u),
                }
            }
            t = next;
        }
    }

    /// Whether `shard` is dark at instant `t`.
    pub fn shard_down_at(&self, shard: usize, t: VirtualTime) -> bool {
        self.shard_windows
            .iter()
            .any(|&(s, from, up)| s == shard && covers(from, up, t))
    }

    /// Earliest instant `>= t` at which `shard` is serving again
    /// (shape of [`node_next_up`](Self::node_next_up)).
    pub fn shard_next_up(&self, shard: usize, t: VirtualTime) -> Option<VirtualTime> {
        let mut t = t;
        loop {
            let down = self
                .shard_windows
                .iter()
                .filter(|&&(s, from, up)| s == shard && covers(from, up, t))
                .map(|&(_, _, up)| up)
                .collect::<Vec<_>>();
            if down.is_empty() {
                return Some(t);
            }
            let mut next = t;
            for up in down {
                match up {
                    None => return None,
                    Some(u) => next = next.max(u),
                }
            }
            t = next;
        }
    }

    /// If a WAN drop window is open at `t`, the instant it closes
    /// (the latest close over overlapping windows); `None` when the
    /// WAN is clean at `t`.
    pub fn drop_until(&self, t: VirtualTime) -> Option<VirtualTime> {
        self.drop_windows
            .iter()
            .filter(|&&(open, close)| open <= t && t < close)
            .map(|&(_, close)| close)
            .max()
    }

    /// If any WAN drop window overlaps the span `[start, end)` —
    /// a chunk in flight over that span is lost — the latest close
    /// instant over the overlapping windows; `None` when the WAN is
    /// clean for the whole span.  [`drop_until`](Self::drop_until) is
    /// the instantaneous special case `start == end`.
    pub fn drop_overlapping(&self, start: VirtualTime, end: VirtualTime) -> Option<VirtualTime> {
        self.drop_windows
            .iter()
            .filter(|&&(open, close)| open < end && start < close)
            .map(|&(_, close)| close)
            .max()
            .or_else(|| self.drop_until(start))
    }

    /// The eviction storms, as `(at, node, bytes)` in time order.
    pub fn evict_storms(&self) -> &[(VirtualTime, usize, u64)] {
        &self.storms
    }

    /// Per-node shard/drop-independent down windows (read-only view
    /// for registries adopting the schedule's outages).
    pub fn shard_windows(&self) -> &[(usize, VirtualTime, Option<VirtualTime>)] {
        &self.shard_windows
    }

    /// The schedule-derived half of a run's [`FaultStats`]: injection
    /// counts, node down-time overlapping `[t0, end]`, and total
    /// repair time / repair count for MTTR.  The run merges its own
    /// retry/failover/drop counters on top.
    pub fn stats_over(&self, t0: VirtualTime, end: VirtualTime) -> FaultStats {
        let mut s = FaultStats::default();
        for &(_, fault) in &self.events {
            match fault {
                Fault::NodeCrash { .. } => s.node_crashes += 1,
                Fault::NodeRejoin { .. } => s.node_repairs += 1,
                Fault::ShardOutage { .. } => s.shard_outages += 1,
                Fault::ShardRecover { .. } => {}
                Fault::TransferDrop { .. } => s.drop_windows += 1,
                Fault::CacheEvictStorm { .. } => s.evict_storms += 1,
            }
        }
        for &(_, from, up) in &self.node_windows {
            // clip the window to [t0, end]; an unrepaired window is
            // down through the end of the span
            let lo = from.max(t0);
            let hi = up.unwrap_or(end).min(end);
            if hi > lo {
                s.downtime += hi.since(lo);
            }
            if let Some(u) = up {
                s.repair_time += u.since(from);
            }
        }
        s.permanent_failures = self
            .node_windows
            .iter()
            .filter(|w| w.2.is_none())
            .count() as u64;
        s
    }

    /// Replay the schedule through a calendar [`EventQueue`] — faults
    /// are first-class `(time, seq)` events like everything else in
    /// the DES — and return the stats over the replayed span plus the
    /// queue counters.  Equals [`stats_over`](Self::stats_over) on the
    /// same span; the queue traversal is what a live event loop sees.
    pub fn replay(&self) -> (FaultStats, QueueStats) {
        let mut q: EventQueue<Fault> = EventQueue::with_capacity(self.events.len().max(1));
        q.push_batch(self.events.clone());
        let mut end = VirtualTime::ZERO;
        while let Some((t, fault)) = q.pop() {
            end = end.max(t);
            if let Fault::TransferDrop { until } = fault {
                end = end.max(until);
            }
        }
        for &(_, _, up) in &self.node_windows {
            if let Some(u) = up {
                end = end.max(u);
            }
        }
        (self.stats_over(VirtualTime::ZERO, end), q.stats())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ms(v: u64) -> VirtualTime {
        VirtualTime(v * 1_000_000)
    }

    #[test]
    fn zero_intensity_is_empty() {
        let cfg = FaultConfig::new(1024, 4, Duration::from_secs_f64(60.0), 0.0);
        let mut rng = SimRng::new(42, "fault-schedule");
        let s = FaultSchedule::generate(&cfg, &mut rng);
        assert!(s.is_empty());
        assert_eq!(s.len(), 0);
        assert_eq!(s.stats_over(ms(0), ms(1000)), FaultStats::default());
    }

    #[test]
    fn same_seed_same_schedule() {
        let cfg = FaultConfig::new(4096, 4, Duration::from_secs_f64(60.0), 0.8);
        let a = FaultSchedule::generate(&cfg, &mut SimRng::new(7, "fault-schedule"));
        let b = FaultSchedule::generate(&cfg, &mut SimRng::new(7, "fault-schedule"));
        assert!(!a.is_empty());
        assert_eq!(a.events(), b.events());
        let c = FaultSchedule::generate(&cfg, &mut SimRng::new(8, "fault-schedule"));
        assert_ne!(a.events(), c.events(), "different seed, different chaos");
    }

    #[test]
    fn crash_rejoin_windows() {
        let s = FaultSchedule::from_events(vec![
            (ms(10), Fault::NodeCrash { node: 3 }),
            (ms(30), Fault::NodeRejoin { node: 3 }),
            (ms(50), Fault::NodeCrash { node: 4 }), // never rejoins
        ]);
        assert!(!s.node_down_at(3, ms(9)));
        assert!(s.node_down_at(3, ms(10)));
        assert!(s.node_down_at(3, ms(29)));
        assert!(!s.node_down_at(3, ms(30)), "rejoin instant is up");
        assert_eq!(s.node_next_up(3, ms(15)), Some(ms(30)));
        assert_eq!(s.node_next_up(3, ms(31)), Some(ms(31)));
        assert_eq!(s.node_next_up(4, ms(60)), None, "permanent failure");
        assert!(!s.node_down_at(5, ms(20)), "unlisted nodes are up");
    }

    #[test]
    fn shard_windows_and_drop_windows() {
        let s = FaultSchedule::from_events(vec![
            (ms(5), Fault::ShardOutage { shard: 1 }),
            (ms(25), Fault::ShardRecover { shard: 1 }),
            (ms(10), Fault::TransferDrop { until: ms(20) }),
        ]);
        assert!(s.shard_down_at(1, ms(6)));
        assert!(!s.shard_down_at(0, ms(6)));
        assert_eq!(s.shard_next_up(1, ms(6)), Some(ms(25)));
        assert_eq!(s.drop_until(ms(15)), Some(ms(20)));
        assert_eq!(s.drop_until(ms(20)), None, "window close is clean");
        assert_eq!(s.drop_until(ms(9)), None);
    }

    #[test]
    fn drop_overlapping_catches_in_flight_spans() {
        let s = FaultSchedule::from_events(vec![
            (ms(10), Fault::TransferDrop { until: ms(20) }),
            (ms(15), Fault::TransferDrop { until: ms(30) }),
        ]);
        // span fully before / fully after the windows: clean
        assert_eq!(s.drop_overlapping(ms(0), ms(10)), None, "ends at open");
        assert_eq!(s.drop_overlapping(ms(30), ms(40)), None);
        // span straddling a window edge is hit
        assert_eq!(s.drop_overlapping(ms(5), ms(11)), Some(ms(20)));
        assert_eq!(s.drop_overlapping(ms(19), ms(40)), Some(ms(30)), "latest close wins");
        // span containing both windows
        assert_eq!(s.drop_overlapping(ms(0), ms(100)), Some(ms(30)));
        // degenerate zero-width span matches drop_until
        assert_eq!(s.drop_overlapping(ms(15), ms(15)), s.drop_until(ms(15)));
        assert_eq!(s.drop_overlapping(ms(9), ms(9)), None);
        assert_eq!(FaultSchedule::none().drop_overlapping(ms(0), ms(100)), None);
    }

    #[test]
    fn shifted_moves_every_time() {
        let s = FaultSchedule::from_events(vec![
            (ms(10), Fault::NodeCrash { node: 0 }),
            (ms(20), Fault::NodeRejoin { node: 0 }),
            (ms(5), Fault::TransferDrop { until: ms(8) }),
        ]);
        let moved = s.shifted(ms(100));
        assert!(moved.node_down_at(0, ms(110)));
        assert!(!moved.node_down_at(0, ms(10)));
        assert_eq!(moved.drop_until(ms(106)), Some(ms(108)));
    }

    #[test]
    fn stats_over_counts_and_downtime() {
        let s = FaultSchedule::from_events(vec![
            (ms(10), Fault::NodeCrash { node: 0 }),
            (ms(30), Fault::NodeRejoin { node: 0 }),
            (ms(40), Fault::NodeCrash { node: 1 }), // permanent
            (ms(0), Fault::ShardOutage { shard: 0 }),
            (ms(5), Fault::ShardRecover { shard: 0 }),
            (ms(1), Fault::TransferDrop { until: ms(2) }),
            (ms(3), Fault::CacheEvictStorm { node: 2, bytes: 100 }),
        ]);
        let f = s.stats_over(ms(0), ms(100));
        assert_eq!(f.node_crashes, 2);
        assert_eq!(f.node_repairs, 1);
        assert_eq!(f.shard_outages, 1);
        assert_eq!(f.drop_windows, 1);
        assert_eq!(f.evict_storms, 1);
        assert_eq!(f.permanent_failures, 1);
        // node 0 down 10..30, node 1 down 40..100 (clipped at end)
        assert_eq!(f.downtime, Duration::from_millis(20 + 60));
        assert_eq!(f.repair_time, Duration::from_millis(20));
        assert_eq!(f.mttr(), Duration::from_millis(20));
    }

    #[test]
    fn replay_agrees_with_window_stats() {
        let cfg = FaultConfig::new(512, 4, Duration::from_secs_f64(30.0), 0.6);
        let s = FaultSchedule::generate(&cfg, &mut SimRng::new(11, "fault-schedule"));
        let (replayed, queue) = s.replay();
        assert_eq!(queue.pushes as usize, s.len());
        assert_eq!(queue.pops, queue.pushes, "drained to empty");
        // every injection is one event; shard recovers are injected
        // but not separately counted, and each outage recovers once
        assert_eq!(
            replayed.node_crashes + replayed.node_repairs + replayed.shard_outages
                + replayed.drop_windows + replayed.evict_storms,
            (s.len() as u64) - replayed.shard_outages,
        );
        if replayed.node_repairs > 0 {
            assert!(replayed.downtime > Duration::ZERO);
        }
    }

    #[test]
    fn generated_indices_stay_in_range() {
        let cfg = FaultConfig::new(64, 4, Duration::from_secs_f64(60.0), 1.0);
        let s = FaultSchedule::generate(&cfg, &mut SimRng::new(3, "fault-schedule"));
        for &(t, fault) in s.events() {
            assert!(t.0 <= cfg.horizon.as_nanos() + cfg.mean_downtime.as_nanos() * 2);
            match fault {
                Fault::NodeCrash { node }
                | Fault::NodeRejoin { node }
                | Fault::CacheEvictStorm { node, .. } => assert!(node < 64),
                Fault::ShardOutage { shard } | Fault::ShardRecover { shard } => {
                    assert!(shard < 4)
                }
                Fault::TransferDrop { until } => assert!(until >= t),
            }
        }
    }
}
