//! Virtual-clock arithmetic.
//!
//! `VirtualTime` is an instant (nanoseconds since simulation start);
//! `Duration` is a span. Both are thin `u64` newtypes so they are `Copy`,
//! `Ord`, and hashable, and so accidental mixing of instants and spans is
//! a type error rather than a bug.

use std::fmt;
use std::iter::Sum;
use std::ops::{Add, AddAssign, Mul, Sub};

/// A span of virtual time, in nanoseconds.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct Duration(
    /// Nanoseconds.
    pub u64,
);

impl Duration {
    /// The zero-length span.
    pub const ZERO: Duration = Duration(0);

    /// A span of `ns` nanoseconds (`const`, so lookahead bounds can be
    /// named constants).
    pub const fn from_nanos(ns: u64) -> Self {
        Duration(ns)
    }
    /// A span of `us` microseconds.
    pub fn from_micros(us: u64) -> Self {
        Duration(us * 1_000)
    }
    /// A span of `ms` milliseconds.
    pub fn from_millis(ms: u64) -> Self {
        Duration(ms * 1_000_000)
    }
    /// From (possibly fractional) seconds; saturates at 0 for negatives.
    pub fn from_secs_f64(s: f64) -> Self {
        Duration((s.max(0.0) * 1e9).round() as u64)
    }
    /// Whole nanoseconds in the span.
    pub fn as_nanos(self) -> u64 {
        self.0
    }
    /// The span in (fractional) seconds.
    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / 1e9
    }
    /// Scale by a dimensionless factor (platform overheads, noise).
    pub fn scale(self, factor: f64) -> Self {
        Duration::from_secs_f64(self.as_secs_f64() * factor)
    }
    /// The longer of two spans.
    pub fn max(self, other: Self) -> Self {
        Duration(self.0.max(other.0))
    }
}

impl Add for Duration {
    type Output = Duration;
    fn add(self, rhs: Duration) -> Duration {
        Duration(self.0 + rhs.0)
    }
}

impl AddAssign for Duration {
    fn add_assign(&mut self, rhs: Duration) {
        self.0 += rhs.0;
    }
}

impl Mul<u64> for Duration {
    type Output = Duration;
    fn mul(self, rhs: u64) -> Duration {
        Duration(self.0 * rhs)
    }
}

impl Sum for Duration {
    fn sum<I: Iterator<Item = Duration>>(iter: I) -> Duration {
        iter.fold(Duration::ZERO, |a, b| a + b)
    }
}

impl fmt::Debug for Duration {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:.6}s", self.as_secs_f64())
    }
}

impl fmt::Display for Duration {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = self.as_secs_f64();
        if s >= 1.0 {
            write!(f, "{s:.3}s")
        } else if s >= 1e-3 {
            write!(f, "{:.3}ms", s * 1e3)
        } else {
            write!(f, "{:.1}us", s * 1e6)
        }
    }
}

/// An instant in virtual time (nanoseconds since simulation start).
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct VirtualTime(
    /// Nanoseconds since simulation start.
    pub u64,
);

impl VirtualTime {
    /// Simulation start.
    pub const ZERO: VirtualTime = VirtualTime(0);

    /// Seconds since simulation start.
    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / 1e9
    }
    /// The later of two instants.
    pub fn max(self, other: Self) -> Self {
        VirtualTime(self.0.max(other.0))
    }
    /// Span from an earlier instant (panics if `earlier` is later).
    pub fn since(self, earlier: VirtualTime) -> Duration {
        Duration(self.0 - earlier.0)
    }
}

impl Add<Duration> for VirtualTime {
    type Output = VirtualTime;
    fn add(self, rhs: Duration) -> VirtualTime {
        VirtualTime(self.0 + rhs.0)
    }
}

impl AddAssign<Duration> for VirtualTime {
    fn add_assign(&mut self, rhs: Duration) {
        self.0 += rhs.0;
    }
}

impl Sub for VirtualTime {
    type Output = Duration;
    fn sub(self, rhs: VirtualTime) -> Duration {
        Duration(self.0 - rhs.0)
    }
}

impl fmt::Debug for VirtualTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "t={:.6}s", self.as_secs_f64())
    }
}

impl fmt::Display for VirtualTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "t={:.6}s", self.as_secs_f64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn duration_arithmetic() {
        let a = Duration::from_millis(2);
        let b = Duration::from_micros(500);
        assert_eq!((a + b).as_nanos(), 2_500_000);
        assert_eq!((a * 3).as_nanos(), 6_000_000);
        assert_eq!(Duration::from_secs_f64(1.5).as_nanos(), 1_500_000_000);
    }

    #[test]
    fn negative_seconds_saturate() {
        assert_eq!(Duration::from_secs_f64(-3.0), Duration::ZERO);
    }

    #[test]
    fn scaling() {
        let d = Duration::from_millis(100);
        assert_eq!(d.scale(1.15).as_nanos(), 115_000_000);
        assert_eq!(d.scale(0.0), Duration::ZERO);
    }

    #[test]
    fn instant_span_relationship() {
        let t0 = VirtualTime::ZERO;
        let t1 = t0 + Duration::from_millis(5);
        assert_eq!(t1 - t0, Duration::from_millis(5));
        assert_eq!(t1.since(t0), Duration::from_millis(5));
        assert!(t1 > t0);
    }

    #[test]
    fn display_units() {
        assert_eq!(format!("{}", Duration::from_secs_f64(2.5)), "2.500s");
        assert_eq!(format!("{}", Duration::from_millis(12)), "12.000ms");
        assert_eq!(format!("{}", Duration::from_micros(7)), "7.0us");
    }

    #[test]
    fn sum_of_durations() {
        let total: Duration = (1..=4).map(Duration::from_millis).sum();
        assert_eq!(total, Duration::from_millis(10));
    }
}
