//! Discrete-event simulation substrate.
//!
//! Everything in `harbor` that happens "on the cluster" happens in
//! **virtual time**: container start-up, metadata requests against the
//! parallel filesystem, MPI messages, and the (really-executed) compute
//! segments whose durations come from the PJRT calibration table.  This
//! module provides the primitives the rest of the crate builds on:
//!
//! * [`VirtualTime`] / [`Duration`] — nanosecond-resolution virtual clock
//!   arithmetic (plain newtypes over `u64`/`i64`-free math, `Ord`, cheap).
//! * [`EventQueue`] — a deterministic **calendar queue** of timed events
//!   with FIFO tie-breaking (two events at the same timestamp pop in
//!   push order; simulations are bit-reproducible for a fixed seed) and
//!   O(1) amortised push/pop at paper scale.  `HeapEventQueue` is the
//!   retained `BinaryHeap` reference implementation it is diff-tested
//!   and benchmarked against (doc-hidden: diff-test/bench use only);
//!   [`stats`] holds the scheduler's observability counters.  The
//!   internals guide is docs/DES.md.
//! * [`CellQueue`] / [`PartitionedQueue`] — the conservative parallel
//!   DES over lookahead domains ([`pdes`]): per-domain calendar queues
//!   advanced window-by-window under a lookahead bound, merged
//!   deterministically so the pop stream is byte-identical to the
//!   serial queue for any `--domains` count.
//! * [`FifoResource`] — a `c`-server queueing station with deterministic
//!   service times; models the Lustre metadata server, NICs under
//!   contention, and the registry's upload slots.  Its servers are
//!   tokens in an [`EventQueue`].
//! * [`fault`] — deterministic fault injection: a [`FaultSchedule`] of
//!   typed crashes/outages/drop-windows generated from a [`SimRng`]
//!   stream, replayable through the calendar queue, with
//!   availability/MTTR accounting in [`FaultStats`].
//! * [`LatencyHistogram`] / [`warmup_trim`] / [`is_stationary`] — the
//!   percentile layer: a deterministic log-binned streaming estimator
//!   (bit-identical p50/p99/p999 across machines and `--jobs`) plus
//!   MSER warmup trimming and a stationarity check for open-loop
//!   scenarios.

pub mod fault;
pub mod pdes;
mod queue;
mod resource;
mod rng;
pub mod stats;
mod time;

pub use fault::{Fault, FaultConfig, FaultSchedule};
pub use pdes::{CellQueue, PartitionedQueue, PdesStats};
pub use queue::{EventQueue, HeapEventQueue};
pub use resource::FifoResource;
pub use rng::SimRng;
pub use stats::{is_stationary, warmup_trim, FaultStats, LatencyHistogram, QueueStats};
pub use time::{Duration, VirtualTime};
