//! # harbor
//!
//! A container-deployment simulator and FEM workload suite that reproduces
//! *"Containers for portable, productive and performant scientific
//! computing"* (Hale, Li, Richardson, Wells; 2016).
//!
//! The paper's subject — distributing one container image of a complex
//! scientific stack (FEniCS) and running it without performance penalty on
//! everything from a laptop to a Cray XC30 — is rebuilt here as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **L1/L2 (build-time Python)** — the FEM compute hot-spots (stencil
//!   operators, multigrid smoothers, fused CG fragments) are Pallas
//!   kernels composed into JAX entry points and AOT-lowered to HLO text
//!   (`python/compile/`). Python never runs at simulation time.
//! * **L3 (this crate)** — everything the paper's evaluation touches:
//!   a container substrate (layered images, buildfiles, registry, and
//!   Docker/rkt/Shifter/VM runtime adapters), an HPC cluster model
//!   (Edison-like nodes, Aries/TCP/shared-memory fabrics, a Lustre-like
//!   parallel filesystem with metadata-server contention), a simulated
//!   MPI layer, the distributed FEM drivers that execute the AOT
//!   artifacts through PJRT, and the benchmark harness that regenerates
//!   every figure in the paper's evaluation (Figs 2–5).
//!
//! See `docs/ARCHITECTURE.md` for the module map with per-layer
//! diagrams, `DESIGN.md` for the substitution table (what the paper ran
//! on real hardware → what is simulated here and why the mechanism is
//! preserved), and `EXPERIMENTS.md` for paper-vs-measured results.
//!
//! ## Crate layout
//!
//! | module | role |
//! |---|---|
//! | [`des`] | virtual clock, calendar-queue event scheduler, FIFO resources — the simulation substrate (docs/DES.md) |
//! | [`container`] | images, layer store, buildfile parser/builder, registry, runtimes, and the fleet distribution tier (sharded registry, node-local caches, peer fan-out) |
//! | [`cluster`] | machine specs (workstation / Edison), nodes, job launcher |
//! | [`net`] | interconnect fabrics: shared-memory, Aries, TCP (α-β + contention) |
//! | [`fs`] | filesystems: local disk, Lustre-like parallel FS, loop-mounted image FS |
//! | [`mpi`] | simulated MPI: communicators, collectives, halo exchange, ABI resolver |
//! | [`runtime`] | PJRT: load AOT HLO artifacts, compile, execute, calibrate |
//! | [`fem`] | structured grids, domain decomposition, CG / multigrid / LU drivers |
//! | [`pyimport`] | the "Python import problem": module graph replayed against the FS |
//! | [`workload`] | the paper's benchmark programs (Figs 2, 3, 4, 5) |
//! | [`platform`] | execution-platform profiles (native / docker / rkt / VM / Shifter) |
//! | [`bench`] | repetition harness, statistics, paper-style report rendering |
//! | [`config`] | experiment configuration and evaluation-matrix expansion |
//! | [`scenario`] | pluggable `Scenario` trait, registry, and the deterministic parallel matrix runner |
//! | [`coordinator`] | Fig 1 pipeline + dispatch into the scenario registry |
//! | [`metrics`] | phase timers and per-phase breakdowns |

#![warn(missing_docs)]

pub mod bench;
pub mod cluster;
pub mod config;
pub mod container;
pub mod coordinator;
pub mod des;
pub mod fem;
pub mod fs;
pub mod metrics;
pub mod mpi;
pub mod net;
pub mod platform;
pub mod pyimport;
pub mod runtime;
pub mod scenario;
pub mod util;
pub mod workload;

pub use platform::Platform;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
