//! Distributed geometric-multigrid V-cycles (the HPGMG-FE engine).
//!
//! Each rank owns one block per level of the ladder 32³ → 16³ → 8³ → 4³
//! (the shapes the smoother/residual/transfer artifacts were exported
//! at).  A V-cycle smooths with halo exchange at every level, restricts
//! the residual, recurses, and applies the coarse correction; the
//! coarsest level is solved by heavy Jacobi smoothing *with halo
//! exchange between sweeps* — a genuinely global coarse solve (vs
//! HPGMG's agglomeration; DESIGN.md §2 documents the substitution).
//! Block-local coarse solves (zero halos) stall on smooth modes, which
//! is why the exchange matters.

use anyhow::{bail, Result};

use crate::mpi::Comm;
use crate::runtime::TensorBuf;

use super::exec::{ComputeScale, Exec};
use super::grid::{exchange_halos, Decomp, LocalField};

/// The exported ladder (fine → coarse block edges).
pub const LADDER: [usize; 4] = [32, 16, 8, 4];

/// Sweeps of halo-exchanged Jacobi at the coarsest level (the global
/// coarse "solve"). The coarsest global grid is small (ranks^(1/3) * 4
/// per axis), where Jacobi's O(h^2) factor is benign.
pub const COARSE_SWEEPS: usize = 48;

/// Multigrid run configuration.
#[derive(Debug, Clone)]
pub struct GmgConfig {
    /// Pre/post smoothing sweeps per level.
    pub nu: usize,
    /// V-cycles to run.
    pub cycles: usize,
    /// Index into [`LADDER`] of the fine level (0 = 32³ blocks; 1 = 16³;
    /// 2 = 8³ — the Fig 5 problem-size axis).
    pub fine_level: usize,
}

impl Default for GmgConfig {
    fn default() -> Self {
        GmgConfig { nu: 2, cycles: 4, fine_level: 0 }
    }
}

/// Outcome of a multigrid run.
#[derive(Debug, Clone)]
pub struct GmgOutcome {
    /// V-cycles performed.
    pub cycles: usize,
    /// ‖r‖₂ after each cycle (real mode; empty in modeled mode).
    pub residual_history: Vec<f64>,
    /// Per-rank interior solutions at the fine level (real mode).
    pub solution: Option<Vec<Vec<f32>>>,
}

/// State per level (real mode): u and f per rank.
struct Level {
    n: usize,
    u: Vec<Vec<f32>>, // per-rank interiors
    f: Vec<Vec<f32>>,
}

/// Run `cfg.cycles` V-cycles on `A u = f` (fine blocks are 32³).
pub fn vcycles(
    exec: &mut Exec,
    comm: &mut Comm,
    scale: &mut ComputeScale,
    decomp: &Decomp,
    rhs: &[Vec<f32>],
    cfg: &GmgConfig,
) -> Result<GmgOutcome> {
    let fine = cfg.fine_level;
    if fine >= LADDER.len() - 1 {
        bail!("fine_level {} leaves no coarse levels", fine);
    }
    if decomp.n_local != LADDER[fine] {
        bail!(
            "fine blocks must be {}³ for fine_level {fine} (got {}³)",
            LADDER[fine],
            decomp.n_local
        );
    }
    let ranks = decomp.ranks();

    if !exec.is_real() {
        for _ in 0..cfg.cycles {
            modeled_vcycle(exec, comm, scale, decomp, fine, cfg.nu)?;
            comm.allreduce(8); // residual-norm check per cycle
        }
        return Ok(GmgOutcome {
            cycles: cfg.cycles,
            residual_history: Vec::new(),
            solution: None,
        });
    }

    if rhs.len() != ranks {
        bail!("real mode needs one RHS per rank");
    }
    let block = LADDER[fine].pow(3);
    for (r, b) in rhs.iter().enumerate() {
        if b.len() != block {
            bail!("rank {r}: rhs length {} != {block}", b.len());
        }
    }

    let mut state = Level {
        n: LADDER[fine],
        u: vec![vec![0.0; block]; ranks],
        f: rhs.to_vec(),
    };
    let mut history = Vec::with_capacity(cfg.cycles);
    for _ in 0..cfg.cycles {
        real_vcycle(exec, comm, scale, decomp, &mut state, fine, cfg.nu)?;
        history.push(residual_norm(exec, comm, scale, decomp, &state, fine)?);
    }
    Ok(GmgOutcome {
        cycles: cfg.cycles,
        residual_history: history,
        solution: Some(state.u),
    })
}

fn level_decomp(decomp: &Decomp, level: usize) -> Decomp {
    let mut d = decomp.clone();
    d.n_local = LADDER[level];
    d
}

/// Timing-only V-cycle at `level`.
///
/// PERF: entry names are formatted and cost-looked-up once per level
/// invocation (not per rank/sweep), and the halo patterns are built
/// once — the modeled ladder is pure arithmetic after that. Kernel
/// charges advance whole rank classes (one jitter draw per phase), and
/// the first halo phase after every synchronising collective runs in
/// O(classes); later sweeps in a cycle start from class-divergent
/// clocks, where `exchange_uniform` transparently falls back to the
/// per-rank message replay — so a batched and a plain communicator
/// produce bit-identical clocks (tests/batched_equivalence.rs).
fn modeled_vcycle(
    exec: &mut Exec,
    comm: &mut Comm,
    scale: &mut ComputeScale,
    decomp: &Decomp,
    level: usize,
    nu: usize,
) -> Result<()> {
    let n = LADDER[level];
    let d = level_decomp(decomp, level);
    let Exec::Modeled { table } = exec else {
        unreachable!("modeled_vcycle is only called in modeled mode");
    };
    let smooth_cost = table.cost(&format!("smooth3d_n{n}"));
    let pattern = d.halo_pattern_for(comm, (n * n * 4) as u64);

    let smooth_phase =
        |comm: &mut Comm, scale: &mut ComputeScale| {
            comm.exchange_uniform(&pattern);
            comm.advance_uniform(scale.apply_pub(smooth_cost));
        };

    if level == LADDER.len() - 1 {
        for _ in 0..COARSE_SWEEPS {
            smooth_phase(comm, scale);
        }
        return Ok(());
    }

    for _ in 0..nu {
        smooth_phase(comm, scale);
    }
    let resid_cost = table.cost(&format!("resid3d_n{n}"));
    let restrict_cost = table.cost(&format!("restrict3d_n{n}"));
    comm.exchange_uniform(&pattern);
    comm.advance_uniform(scale.apply_pub(resid_cost));
    // residual halo exchange feeds the variational (P^T) restriction
    comm.exchange_uniform(&pattern);
    comm.advance_uniform(scale.apply_pub(restrict_cost));
    modeled_vcycle(exec, comm, scale, decomp, level + 1, nu)?;
    // coarse-correction halo exchange feeds the trilinear prolongation
    let nc = LADDER[level + 1];
    let Exec::Modeled { table } = exec else { unreachable!() };
    let prolong_cost = table.cost(&format!("prolong_add3d_n{nc}"));
    let coarse_pattern =
        level_decomp(decomp, level + 1).halo_pattern_for(comm, (nc * nc * 4) as u64);
    comm.exchange_uniform(&coarse_pattern);
    comm.advance_uniform(scale.apply_pub(prolong_cost));
    for _ in 0..nu {
        smooth_phase(comm, scale);
    }
    Ok(())
}

/// Real-data V-cycle at `level` over `lev` state.
fn real_vcycle(
    exec: &mut Exec,
    comm: &mut Comm,
    scale: &mut ComputeScale,
    decomp: &Decomp,
    lev: &mut Level,
    level: usize,
    nu: usize,
) -> Result<()> {
    let n = lev.n;
    let ranks = decomp.ranks();
    let d = level_decomp(decomp, level);

    if level == LADDER.len() - 1 {
        // global coarse solve: heavy smoothing with halo exchange
        for _ in 0..COARSE_SWEEPS {
            smooth_once(exec, comm, scale, &d, lev)?;
        }
        return Ok(());
    }
    let _ = ranks;

    for _ in 0..nu {
        smooth_once(exec, comm, scale, &d, lev)?;
    }

    // residual, residual-halo exchange, then variational restriction
    let u_fields = exchange(&d, &lev.u, comm);
    let mut resid: Vec<Vec<f32>> = Vec::with_capacity(ranks);
    for r in 0..ranks {
        let u_pad = TensorBuf::new(vec![n + 2, n + 2, n + 2], u_fields[r].data.clone());
        let f = TensorBuf::new(vec![n, n, n], lev.f[r].clone());
        resid.push(
            exec.call(comm, scale, r, &format!("resid3d_n{n}"), &[u_pad, f])?
                .unwrap()[0]
                .data
                .clone(),
        );
    }
    let r_fields = exchange(&d, &resid, comm);
    let mut coarse_f: Vec<Vec<f32>> = Vec::with_capacity(ranks);
    for r in 0..ranks {
        let rc = exec
            .call(
                comm,
                scale,
                r,
                &format!("restrict3d_n{n}"),
                &[TensorBuf::new(
                    vec![n + 2, n + 2, n + 2],
                    r_fields[r].data.clone(),
                )],
            )?
            .unwrap()[0]
            .data
            .clone();
        coarse_f.push(rc);
    }

    let nc = LADDER[level + 1];
    let mut coarse = Level {
        n: nc,
        u: vec![vec![0.0; nc * nc * nc]; ranks],
        f: coarse_f,
    };
    real_vcycle(exec, comm, scale, decomp, &mut coarse, level + 1, nu)?;

    // prolong + correct: exchange the coarse correction's halos first so
    // interpolation at block interfaces uses neighbour values
    let e_fields = exchange(&level_decomp(decomp, level + 1), &coarse.u, comm);
    for r in 0..ranks {
        let u_fine = TensorBuf::new(vec![n, n, n], lev.u[r].clone());
        let e = TensorBuf::new(vec![nc + 2, nc + 2, nc + 2], e_fields[r].data.clone());
        let out = exec
            .call(comm, scale, r, &format!("prolong_add3d_n{nc}"), &[u_fine, e])?
            .unwrap();
        lev.u[r] = out[0].data.clone();
    }

    for _ in 0..nu {
        smooth_once(exec, comm, scale, &d, lev)?;
    }
    Ok(())
}

fn exchange(d: &Decomp, interiors: &[Vec<f32>], comm: &mut Comm) -> Vec<LocalField> {
    let mut fields: Vec<LocalField> = interiors
        .iter()
        .map(|u| LocalField::from_interior(d.n_local, u))
        .collect();
    exchange_halos(d, &mut fields, comm);
    fields
}

fn smooth_once(
    exec: &mut Exec,
    comm: &mut Comm,
    scale: &mut ComputeScale,
    d: &Decomp,
    lev: &mut Level,
) -> Result<()> {
    let n = lev.n;
    let fields = exchange(d, &lev.u, comm);
    for r in 0..d.ranks() {
        let u_pad = TensorBuf::new(vec![n + 2, n + 2, n + 2], fields[r].data.clone());
        let f = TensorBuf::new(vec![n, n, n], lev.f[r].clone());
        let out = exec
            .call(comm, scale, r, &format!("smooth3d_n{n}"), &[u_pad, f])?
            .unwrap();
        lev.u[r] = out[0].data.clone();
    }
    Ok(())
}

/// Global ‖f - A u‖₂ at the fine level (one allreduce).
fn residual_norm(
    exec: &mut Exec,
    comm: &mut Comm,
    scale: &mut ComputeScale,
    decomp: &Decomp,
    lev: &Level,
    fine_level: usize,
) -> Result<f64> {
    let n = lev.n;
    let d = level_decomp(decomp, fine_level);
    let fields = exchange(&d, &lev.u, comm);
    let mut total = 0.0f64;
    for r in 0..decomp.ranks() {
        let u_pad = TensorBuf::new(vec![n + 2, n + 2, n + 2], fields[r].data.clone());
        let f = TensorBuf::new(vec![n, n, n], lev.f[r].clone());
        let resid = exec
            .call(comm, scale, r, &format!("resid3d_n{n}"), &[u_pad, f])?
            .unwrap()[0]
            .data
            .clone();
        let out = exec
            .call(
                comm,
                scale,
                r,
                &format!("norm2_n{n}"),
                &[TensorBuf::new(vec![n, n, n], resid)],
            )?
            .unwrap();
        total += out[0].data[0] as f64;
    }
    comm.allreduce(8);
    Ok(total.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{launch, MachineSpec};
    use crate::net::{Fabric, FabricKind};
    use crate::runtime::CalibrationTable;

    #[test]
    fn modeled_vcycles_cost_time_and_traffic() {
        let table = CalibrationTable::builtin_fallback();
        let decomp = Decomp::new(8, 32);
        let m = MachineSpec::edison();
        let mut comm = Comm::new(launch(&m, 8).unwrap(), Fabric::by_kind(FabricKind::Aries));
        let out = vcycles(
            &mut Exec::Modeled { table: &table },
            &mut comm,
            &mut ComputeScale::none(),
            &decomp,
            &[],
            &GmgConfig { nu: 2, cycles: 3, ..Default::default() },
        )
        .unwrap();
        assert_eq!(out.cycles, 3);
        assert!(comm.max_clock().as_secs_f64() > 0.0);
        assert!(comm.stats().p2p_messages > 0);
        assert_eq!(comm.stats().allreduces, 3);
    }

    #[test]
    fn modeled_vcycles_batched_bit_identical_to_per_rank() {
        // GMG stresses the fallback: only the first halo phase after a
        // sync is class-uniform; the rest must transparently materialise
        let table = CalibrationTable::builtin_fallback();
        let m = MachineSpec::edison();
        for ranks in [8usize, 48, 96] {
            let decomp = Decomp::new(ranks, 32);
            let run = |batched: bool| {
                let mut comm =
                    Comm::new(launch(&m, ranks).unwrap(), Fabric::by_kind(FabricKind::Aries));
                if batched {
                    comm.set_classes(decomp.rank_classes(comm.allocation()));
                }
                let mut scale = crate::fem::exec::ComputeScale::new(1.0, 1.0, 5, 0.015);
                vcycles(
                    &mut Exec::Modeled { table: &table },
                    &mut comm,
                    &mut scale,
                    &decomp,
                    &[],
                    &GmgConfig { nu: 2, cycles: 2, ..Default::default() },
                )
                .unwrap();
                (0..ranks).map(|r| comm.clock(r)).collect::<Vec<_>>()
            };
            assert_eq!(run(true), run(false), "ranks {ranks}");
        }
    }

    #[test]
    fn wrong_fine_size_rejected() {
        let table = CalibrationTable::builtin_fallback();
        let decomp = Decomp::new(8, 16);
        let m = MachineSpec::edison();
        let mut comm = Comm::new(launch(&m, 8).unwrap(), Fabric::by_kind(FabricKind::Aries));
        assert!(vcycles(
            &mut Exec::Modeled { table: &table },
            &mut comm,
            &mut ComputeScale::none(),
            &decomp,
            &[],
            &GmgConfig::default(),
        )
        .is_err());
    }

    #[test]
    fn deeper_nu_costs_more() {
        let table = CalibrationTable::builtin_fallback();
        let decomp = Decomp::new(8, 32);
        let m = MachineSpec::edison();
        let run = |nu| {
            let mut comm = Comm::new(launch(&m, 8).unwrap(), Fabric::by_kind(FabricKind::Aries));
            vcycles(
                &mut Exec::Modeled { table: &table },
                &mut comm,
                &mut ComputeScale::none(),
                &decomp,
                &[],
                &GmgConfig { nu, cycles: 1, ..Default::default() },
            )
            .unwrap();
            comm.max_clock().as_secs_f64()
        };
        assert!(run(4) > run(1));
    }
}
