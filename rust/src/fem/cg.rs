//! Conjugate-gradient drivers.
//!
//! [`distributed_cg`] is the solver inside the paper's Fig 3/4 test
//! program (and the elasticity/Poisson tests of Fig 2 when run on one
//! rank): per-iteration it exchanges halos, applies the stencil operator
//! through the AOT `cg_apdot` artifact, and reduces scalars through the
//! simulated MPI — the same control flow whether compute is `Real`
//! (actual PJRT numerics) or `Modeled` (calibrated costs only).
//!
//! [`precond_cg_single`] is the Fig 2 "Poisson AMG" stand-in: CG
//! preconditioned with one geometric-multigrid V-cycle per iteration
//! (AMG → GMG substitution, DESIGN.md §2), single rank.

use anyhow::{bail, Result};

use crate::mpi::Comm;
use crate::runtime::TensorBuf;

use super::exec::{ComputeScale, Exec};
use super::grid::{exchange_halos, Decomp, LocalField};

/// Solver configuration.
#[derive(Debug, Clone)]
pub struct CgConfig {
    /// Relative-residual tolerance (‖r‖ / ‖b‖).
    pub tol: f64,
    /// Iteration cap before declaring non-convergence.
    pub max_iters: usize,
    /// Iteration count to simulate in `Modeled` mode (no residual is
    /// available without data; use [`estimate_cg_iters`]).
    pub modeled_iters: usize,
    /// Solve the vector Lamé system instead of scalar Poisson
    /// (requires `n_local == 16`, the exported elasticity shape).
    pub elasticity: bool,
}

impl Default for CgConfig {
    fn default() -> Self {
        CgConfig {
            tol: 1e-5,
            max_iters: 2000,
            modeled_iters: 64,
            elasticity: false,
        }
    }
}

/// Solver result.
#[derive(Debug, Clone)]
pub struct CgOutcome {
    /// Iterations performed.
    pub iters: usize,
    /// Final relative residual (`None` in modeled mode).
    pub rel_residual: Option<f64>,
    /// Per-rank interior solutions (real mode only).
    pub solution: Option<Vec<Vec<f32>>>,
}

/// Practical CG iteration estimate for the scaled 7-point Poisson
/// operator at global resolution `n_global`, to relative tolerance
/// `tol`: CG needs O(√κ) = O(n) iterations with a tol-dependent log
/// factor.  The constant 1.4 is fitted against *real* distributed
/// solves (44 iterations at n = 32, tol = 1e-5; see the integration
/// test `cg_iteration_estimate_matches_real_runs`).
pub fn estimate_cg_iters(n_global: usize, tol: f64) -> usize {
    let tol_factor = (2.0 / tol).ln() / (2.0f64 / 1e-5).ln();
    (1.4 * n_global as f64 * tol_factor).ceil().max(4.0) as usize
}

/// Distributed CG for `A x = b` on `decomp`'s grid.
///
/// `rhs`: per-rank interior right-hand sides (real mode; pass `&[]` in
/// modeled mode). Scalar problems use length-`n³` interiors; elasticity
/// uses `3·n³` (component-major).
pub fn distributed_cg(
    exec: &mut Exec,
    comm: &mut Comm,
    scale: &mut ComputeScale,
    decomp: &Decomp,
    rhs: &[Vec<f32>],
    cfg: &CgConfig,
) -> Result<CgOutcome> {
    let ranks = decomp.ranks();
    let n = decomp.n_local;
    let ncomp = if cfg.elasticity { 3 } else { 1 };
    let local_len = ncomp * n * n * n;
    let (apdot_entry, update_entry, pupdate_entry) = entries(n, cfg.elasticity)?;

    if comm.size() != ranks {
        bail!("communicator has {} ranks, decomposition {}", comm.size(), ranks);
    }

    if exec.is_real() {
        if rhs.len() != ranks {
            bail!("real mode needs one RHS per rank ({} given, {ranks} ranks)", rhs.len());
        }
        for (r, b) in rhs.iter().enumerate() {
            if b.len() != local_len {
                bail!("rank {r}: RHS length {} != {local_len}", b.len());
            }
        }
    }

    // ---- modeled mode: charge the phase structure, no data -------------
    if let Exec::Modeled { table } = exec {
        // PERF: hoist the per-entry calibration lookups and the halo
        // pattern out of the iteration loop (they are loop-invariant;
        // doing them per call made the BTreeMap the hot path of large
        // simulations — see EXPERIMENTS.md §Perf). On a class-batched
        // communicator every phase below runs in O(classes): the loop
        // enters each halo phase from a synchronised state (allreduce on
        // the previous iteration), so `exchange_uniform` never has to
        // fall back, and the uniform kernel charges advance whole
        // classes. On a plain communicator the identical calls replay
        // the per-rank message list and advance every rank — the two
        // paths are VirtualTime-identical by construction (see
        // tests/batched_equivalence.rs).
        let apdot_cost = table.cost(apdot_entry);
        let update_cost = table.cost(update_entry);
        let pupdate_cost = table.cost(pupdate_entry);
        let pattern = decomp.halo_pattern_for(comm, decomp.face_bytes() * ncomp as u64);
        for _ in 0..cfg.modeled_iters {
            comm.exchange_uniform(&pattern);
            comm.advance_uniform(scale.apply_pub(apdot_cost));
            comm.allreduce(8);
            comm.advance_uniform(scale.apply_pub(update_cost));
            comm.allreduce(8);
            comm.advance_uniform(scale.apply_pub(pupdate_cost));
        }
        return Ok(CgOutcome {
            iters: cfg.modeled_iters,
            rel_residual: None,
            solution: None,
        });
    }

    // ---- real mode: actual numerics -------------------------------------
    let mut x: Vec<Vec<f32>> = vec![vec![0.0; local_len]; ranks];
    let mut r: Vec<Vec<f32>> = rhs.to_vec();
    let mut p: Vec<Vec<f32>> = rhs.to_vec();

    let rr0: f64 = r.iter().flat_map(|v| v.iter()).map(|&v| (v as f64) * v as f64).sum();
    let norm_b = rr0.sqrt().max(1e-30);
    let mut rr = rr0;
    let mut iters = 0;

    for _ in 0..cfg.max_iters {
        // halo exchange on p (per component)
        let mut p_fields = fields_from_flat(decomp, &p, n, ncomp);
        for comp_fields in p_fields.iter_mut() {
            exchange_halos(decomp, comp_fields, comm);
        }

        // Ap and local <p, Ap>
        let mut ap: Vec<Vec<f32>> = Vec::with_capacity(ranks);
        let mut pap = 0.0f64;
        for rank in 0..ranks {
            let input = padded_input(&p_fields, rank, n, ncomp);
            let out = exec
                .call(comm, scale, rank, apdot_entry, &[input])?
                .expect("real mode returns data");
            pap += out[1].data[0] as f64;
            ap.push(out[0].data.clone());
        }
        comm.allreduce(8);

        if pap.abs() < 1e-30 {
            bail!("CG breakdown: <p, Ap> ~ 0 at iteration {iters}");
        }
        let alpha = (rr / pap) as f32;

        // fused update x, r, local rr
        let mut rr_new = 0.0f64;
        for rank in 0..ranks {
            let out = exec
                .call(
                    comm,
                    scale,
                    rank,
                    update_entry,
                    &[
                        TensorBuf::scalar1(alpha),
                        TensorBuf::new(vec![local_len], x[rank].clone()),
                        TensorBuf::new(vec![local_len], r[rank].clone()),
                        TensorBuf::new(vec![local_len], p[rank].clone()),
                        TensorBuf::new(vec![local_len], ap[rank].clone()),
                    ],
                )?
                .expect("real mode returns data");
            x[rank] = out[0].data.clone();
            r[rank] = out[1].data.clone();
            rr_new += out[2].data[0] as f64;
        }
        comm.allreduce(8);
        iters += 1;

        if rr_new.sqrt() <= cfg.tol * norm_b {
            rr = rr_new;
            break;
        }

        let beta = (rr_new / rr) as f32;
        for rank in 0..ranks {
            let out = exec
                .call(
                    comm,
                    scale,
                    rank,
                    pupdate_entry,
                    &[
                        TensorBuf::scalar1(beta),
                        TensorBuf::new(vec![local_len], r[rank].clone()),
                        TensorBuf::new(vec![local_len], p[rank].clone()),
                    ],
                )?
                .expect("real mode returns data");
            p[rank] = out[0].data.clone();
        }
        rr = rr_new;
    }

    Ok(CgOutcome {
        iters,
        rel_residual: Some(rr.sqrt() / norm_b),
        solution: Some(x),
    })
}

fn entries(n: usize, elasticity: bool) -> Result<(&'static str, &'static str, &'static str)> {
    Ok(if elasticity {
        if n != 16 {
            bail!("elasticity artifacts are exported at n_local = 16 (got {n})");
        }
        ("cg_apdot_el3d_n16", "cg_update_L12288", "cg_pupdate_L12288")
    } else {
        match n {
            16 => ("cg_apdot_p3d_n16", "cg_update_L4096", "cg_pupdate_L4096"),
            32 => ("cg_apdot_p3d_n32", "cg_update_L32768", "cg_pupdate_L32768"),
            _ => bail!("Poisson artifacts are exported at n_local ∈ {{16, 32}} (got {n})"),
        }
    })
}

/// Per-component halo-padded fields from flat per-rank vectors.
fn fields_from_flat(
    decomp: &Decomp,
    flat: &[Vec<f32>],
    n: usize,
    ncomp: usize,
) -> Vec<Vec<LocalField>> {
    let block = n * n * n;
    (0..ncomp)
        .map(|c| {
            (0..decomp.ranks())
                .map(|r| LocalField::from_interior(n, &flat[r][c * block..(c + 1) * block]))
                .collect()
        })
        .collect()
}

/// Assemble the (possibly multi-component) padded input tensor.
fn padded_input(fields: &[Vec<LocalField>], rank: usize, n: usize, ncomp: usize) -> TensorBuf {
    let np = n + 2;
    if ncomp == 1 {
        TensorBuf::new(vec![np, np, np], fields[0][rank].data.clone())
    } else {
        let mut data = Vec::with_capacity(ncomp * np * np * np);
        for comp_fields in fields {
            data.extend_from_slice(&comp_fields[rank].data);
        }
        TensorBuf::new(vec![ncomp, np, np, np], data)
    }
}

/// Single-rank CG preconditioned by one GMG V-cycle per iteration
/// (the Fig 2 "Poisson AMG" test; n = 32 fixed by the exported shapes).
pub fn precond_cg_single(
    exec: &mut Exec,
    comm: &mut Comm,
    scale: &mut ComputeScale,
    rhs: &[f32],
    tol: f64,
    max_iters: usize,
    modeled_iters: usize,
) -> Result<CgOutcome> {
    const N: usize = 32;
    const L: usize = N * N * N;
    let decomp = Decomp::new(1, N);

    if !exec.is_real() {
        for _ in 0..modeled_iters {
            exec.call(comm, scale, 0, "cg_apdot_p3d_n32", &[])?;
            exec.call(comm, scale, 0, "cg_update_L32768", &[])?;
            exec.call(comm, scale, 0, "precond_vcycle_n32", &[])?;
            exec.call(comm, scale, 0, "dot_L32768", &[])?;
            exec.call(comm, scale, 0, "cg_pupdate_L32768", &[])?;
        }
        return Ok(CgOutcome {
            iters: modeled_iters,
            rel_residual: None,
            solution: None,
        });
    }

    if rhs.len() != L {
        bail!("rhs must be {L} long (32³)");
    }

    let pad = |v: &[f32]| {
        let f = LocalField::from_interior(N, v);
        TensorBuf::new(vec![N + 2, N + 2, N + 2], f.data)
    };
    let flat = |v: Vec<f32>| TensorBuf::new(vec![L], v);

    let mut x = vec![0.0f32; L];
    let mut r = rhs.to_vec();
    let norm_b = r.iter().map(|&v| (v as f64) * v as f64).sum::<f64>().sqrt().max(1e-30);

    // z = M r ; p = z ; rz = <r, z>
    let z0 = exec
        .call(comm, scale, 0, "precond_vcycle_n32", &[flat(r.clone())])?
        .unwrap()[0]
        .data
        .clone();
    let mut p = z0.clone();
    let mut rz = exec
        .call(comm, scale, 0, "dot_L32768", &[flat(r.clone()), flat(z0)])?
        .unwrap()[0]
        .data[0] as f64;
    let mut iters = 0;
    let mut rel = 1.0;

    for _ in 0..max_iters {
        let _ = &decomp; // single rank: halo pad is all-zero Dirichlet
        let out = exec.call(comm, scale, 0, "cg_apdot_p3d_n32", &[pad(&p)])?.unwrap();
        let ap = out[0].data.clone();
        let pap = out[1].data[0] as f64;
        if pap.abs() < 1e-30 {
            bail!("PCG breakdown at iteration {iters}");
        }
        let alpha = (rz / pap) as f32;
        let out = exec
            .call(
                comm,
                scale,
                0,
                "cg_update_L32768",
                &[
                    TensorBuf::scalar1(alpha),
                    flat(x.clone()),
                    flat(r.clone()),
                    flat(p.clone()),
                    flat(ap),
                ],
            )?
            .unwrap();
        x = out[0].data.clone();
        r = out[1].data.clone();
        let rr_new = out[2].data[0] as f64;
        iters += 1;
        rel = rr_new.sqrt() / norm_b;
        if rel <= tol {
            break;
        }
        let z = exec
            .call(comm, scale, 0, "precond_vcycle_n32", &[flat(r.clone())])?
            .unwrap()[0]
            .data
            .clone();
        let rz_new = exec
            .call(comm, scale, 0, "dot_L32768", &[flat(r.clone()), flat(z.clone())])?
            .unwrap()[0]
            .data[0] as f64;
        let beta = (rz_new / rz) as f32;
        let out = exec
            .call(
                comm,
                scale,
                0,
                "cg_pupdate_L32768",
                &[TensorBuf::scalar1(beta), flat(z), flat(p.clone())],
            )?
            .unwrap();
        p = out[0].data.clone();
        rz = rz_new;
    }

    Ok(CgOutcome {
        iters,
        rel_residual: Some(rel),
        solution: Some(vec![x]),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{launch, MachineSpec};
    use crate::net::{Fabric, FabricKind};
    use crate::runtime::CalibrationTable;

    #[test]
    fn iteration_estimate_scales_linearly_in_n() {
        let a = estimate_cg_iters(32, 1e-5);
        let b = estimate_cg_iters(64, 1e-5);
        assert!(b > a && b < 3 * a, "{a} vs {b}");
        assert!(estimate_cg_iters(32, 1e-8) > estimate_cg_iters(32, 1e-3));
        assert!(estimate_cg_iters(1, 1e-5) >= 4);
    }

    #[test]
    fn modeled_cg_charges_phases() {
        let table = CalibrationTable::builtin_fallback();
        let decomp = Decomp::new(8, 16);
        let m = MachineSpec::edison();
        let mut comm = Comm::new(launch(&m, 8).unwrap(), Fabric::by_kind(FabricKind::Aries));
        let mut scale = ComputeScale::none();
        let cfg = CgConfig {
            modeled_iters: 10,
            ..CgConfig::default()
        };
        let out = distributed_cg(
            &mut Exec::Modeled { table: &table },
            &mut comm,
            &mut scale,
            &decomp,
            &[],
            &cfg,
        )
        .unwrap();
        assert_eq!(out.iters, 10);
        assert!(out.solution.is_none());
        assert_eq!(comm.stats().allreduces, 20);
        // 10 iters x halo messages
        assert!(comm.stats().p2p_messages > 0);
        assert!(comm.max_clock().as_secs_f64() > 0.0);
    }

    #[test]
    fn modeled_cg_tcp_slower_than_aries() {
        let table = CalibrationTable::builtin_fallback();
        let decomp = Decomp::new(48, 32);
        let m = MachineSpec::edison();
        let cfg = CgConfig {
            modeled_iters: 20,
            ..CgConfig::default()
        };
        let run = |kind| {
            let mut comm = Comm::new(launch(&m, 48).unwrap(), Fabric::by_kind(kind));
            distributed_cg(
                &mut Exec::Modeled { table: &table },
                &mut comm,
                &mut ComputeScale::none(),
                &decomp,
                &[],
                &cfg,
            )
            .unwrap();
            comm.max_clock().as_secs_f64()
        };
        let aries = run(FabricKind::Aries);
        let tcp = run(FabricKind::TcpEthernet);
        assert!(tcp > 3.0 * aries, "aries {aries}, tcp {tcp}");
    }

    #[test]
    fn modeled_cg_batched_is_bit_identical_to_per_rank() {
        let table = CalibrationTable::builtin_fallback();
        let m = MachineSpec::edison();
        for ranks in [1usize, 8, 48, 192] {
            let decomp = Decomp::new(ranks, 16);
            let cfg = CgConfig {
                modeled_iters: 7,
                ..CgConfig::default()
            };
            let run = |batched: bool| {
                let mut comm =
                    Comm::new(launch(&m, ranks).unwrap(), Fabric::by_kind(FabricKind::Aries));
                if batched {
                    comm.set_classes(decomp.rank_classes(comm.allocation()));
                }
                // jitter ON: the single-draw-per-phase semantics must
                // make the paths identical even with noise
                let mut scale = ComputeScale::new(1.0, 1.0, 11, 0.02);
                distributed_cg(
                    &mut Exec::Modeled { table: &table },
                    &mut comm,
                    &mut scale,
                    &decomp,
                    &[],
                    &cfg,
                )
                .unwrap();
                let clocks: Vec<_> = (0..ranks).map(|r| comm.clock(r)).collect();
                (clocks, comm.stats().p2p_messages, comm.stats().p2p_bytes)
            };
            let (bc, bm, bb) = run(true);
            let (pc, pm, pb) = run(false);
            assert_eq!(bc, pc, "ranks {ranks}: clocks diverged");
            assert_eq!((bm, bb), (pm, pb), "ranks {ranks}: stats diverged");
        }
    }

    #[test]
    fn wrong_rank_count_is_rejected() {
        let table = CalibrationTable::builtin_fallback();
        let decomp = Decomp::new(8, 16);
        let m = MachineSpec::edison();
        let mut comm = Comm::new(launch(&m, 4).unwrap(), Fabric::by_kind(FabricKind::Aries));
        let err = distributed_cg(
            &mut Exec::Modeled { table: &table },
            &mut comm,
            &mut ComputeScale::none(),
            &decomp,
            &[],
            &CgConfig::default(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("ranks"));
    }

    #[test]
    fn unsupported_block_size_is_rejected() {
        assert!(entries(24, false).is_err());
        assert!(entries(32, true).is_err());
        assert!(entries(16, true).is_ok());
    }
}
