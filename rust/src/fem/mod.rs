//! Structured-grid FEM drivers — the "FEniCS" of this reproduction.
//!
//! The paper's test programs are Poisson and elasticity solves plus the
//! HPGMG-FE benchmark.  This module owns their distributed drivers:
//!
//! * [`grid`] — 3D Cartesian domain decomposition, per-rank halo-padded
//!   fields, and the face-exchange machinery (real data movement *and*
//!   the message lists the simulated MPI charges for).
//! * [`exec`] — the compute-execution abstraction: `Real` runs the AOT
//!   artifacts through PJRT and charges measured wall time; `Modeled`
//!   charges calibrated costs only (for 24–192-rank simulations).
//! * [`cg`] — distributed conjugate gradients over the exported CG
//!   fragments (`cg_apdot` / `cg_update` / `cg_pupdate`), identical
//!   control flow in both execution modes; plus the single-domain
//!   multigrid-preconditioned CG used by the Fig 2 "Poisson AMG" test.
//! * [`gmg`] — the distributed V-cycle ladder used by HPGMG (Fig 5).
//! * [`lu`] — the 2D dense-LU direct solve (Fig 2 "Poisson LU").
//!
//! Numerical ground truth: with `Exec::Real` the drivers produce actual
//! solutions that integration tests compare against the pure-jnp oracle
//! (to f32 tolerance); `Exec::Modeled` runs the same phase structure in
//! virtual time only.

pub mod cg;
pub mod exec;
pub mod gmg;
pub mod grid;
pub mod lu;

pub use cg::{estimate_cg_iters, CgConfig, CgOutcome};
pub use exec::Exec;
pub use grid::{factor3, Decomp, LocalField};
