//! Dense-LU direct solve (the Fig 2 "Poisson LU" test).
//!
//! The paper's test solves a 2D Poisson problem with a direct LU
//! factorisation; the exported `lu_poisson2d_n32` artifact assembles the
//! dense scaled 5-point matrix in-graph and solves it (factorisation
//! included, as in the paper's reported times).

use anyhow::Result;

use crate::mpi::Comm;
use crate::runtime::TensorBuf;

use super::exec::{ComputeScale, Exec};

/// Grid edge of the exported 2D problem.
pub const LU_N: usize = 32;

/// Solve the 2D problem; returns the solution grid in real mode.
pub fn lu_solve(
    exec: &mut Exec,
    comm: &mut Comm,
    scale: &mut ComputeScale,
    rhs: &[f32],
) -> Result<Option<Vec<f32>>> {
    if !exec.is_real() {
        exec.call(comm, scale, 0, "lu_poisson2d_n32", &[])?;
        return Ok(None);
    }
    let f = TensorBuf::new(vec![LU_N, LU_N], rhs.to_vec());
    let out = exec.call(comm, scale, 0, "lu_poisson2d_n32", &[f])?.unwrap();
    Ok(Some(out[0].data.clone()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{launch, MachineSpec};
    use crate::net::{Fabric, FabricKind};
    use crate::runtime::CalibrationTable;

    #[test]
    fn modeled_lu_charges_time() {
        let table = CalibrationTable::builtin_fallback();
        let m = MachineSpec::workstation();
        let mut comm = Comm::new(launch(&m, 1).unwrap(), Fabric::by_kind(FabricKind::SharedMem));
        let got = lu_solve(
            &mut Exec::Modeled { table: &table },
            &mut comm,
            &mut ComputeScale::none(),
            &[],
        )
        .unwrap();
        assert!(got.is_none());
        assert!(comm.max_clock().as_secs_f64() > 0.0);
    }

    #[test]
    fn real_lu_inverts_the_operator() {
        if !crate::runtime::artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let mut engine = crate::runtime::Engine::open_default().unwrap();
        let m = MachineSpec::workstation();
        let mut comm = Comm::new(launch(&m, 1).unwrap(), Fabric::by_kind(FabricKind::SharedMem));
        // f = A u_true for a known u_true; the solve must recover it
        let n = LU_N;
        let u_true: Vec<f32> = (0..n * n)
            .map(|i| ((i % 13) as f32 - 6.0) * 0.1)
            .collect();
        // apply the scaled 5-point operator in plain rust
        let at = |z: &Vec<f32>, y: isize, x: isize| -> f32 {
            if y < 0 || x < 0 || y >= n as isize || x >= n as isize {
                0.0
            } else {
                z[(y as usize) * n + x as usize]
            }
        };
        let mut f = vec![0.0f32; n * n];
        for y in 0..n as isize {
            for x in 0..n as isize {
                f[(y as usize) * n + x as usize] = 4.0 * at(&u_true, y, x)
                    - at(&u_true, y - 1, x)
                    - at(&u_true, y + 1, x)
                    - at(&u_true, y, x - 1)
                    - at(&u_true, y, x + 1);
            }
        }
        let got = lu_solve(
            &mut Exec::Real { engine: &mut engine },
            &mut comm,
            &mut ComputeScale::none(),
            &f,
        )
        .unwrap()
        .unwrap();
        let err: f32 = got
            .iter()
            .zip(&u_true)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max);
        assert!(err < 5e-3, "max error {err}");
        assert!(comm.max_clock().as_secs_f64() > 0.0);
    }
}
