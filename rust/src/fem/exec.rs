//! Execution modes for per-rank compute segments.
//!
//! `Real` actually executes the AOT artifact through PJRT, measures the
//! wall time, and charges it (scaled by the platform's compute factor
//! and the machine's run-to-run jitter) to the rank's virtual clock.
//! `Modeled` charges the calibrated per-call cost instead and returns no
//! data — the mode used for 24–192-rank simulations, where executing
//! every rank's kernels for real would make the simulator itself the
//! bottleneck without changing the figure shapes (DESIGN.md §3).

use std::time::Instant;

use anyhow::Result;

use crate::des::{Duration, SimRng};
use crate::mpi::Comm;
use crate::runtime::{CalibrationTable, Engine, TensorBuf};

/// How compute segments execute.
pub enum Exec<'a> {
    /// Run PJRT for real; charge measured time.
    Real {
        /// The PJRT engine executing AOT artifacts.
        engine: &'a mut Engine,
    },
    /// Charge calibrated cost; no data produced.
    Modeled {
        /// Measured (or fallback) per-artifact costs.
        table: &'a CalibrationTable,
    },
}

/// Per-run scaling applied to every compute segment.
#[derive(Debug, Clone)]
pub struct ComputeScale {
    /// Platform compute factor (VM ≈ 1.15, others 1.0).
    pub factor: f64,
    /// Architecture penalty for generic binaries on tuned kernels
    /// (Fig 5a; 1.0 unless the workload opts in).
    pub arch_factor: f64,
    /// Run-to-run jitter source (error bars).
    pub rng: SimRng,
    /// Jitter amplitude (from the machine spec).
    pub jitter_eps: f64,
}

impl ComputeScale {
    /// Scaling with the given platform factor, arch factor, and
    /// seeded multiplicative jitter.
    pub fn new(factor: f64, arch_factor: f64, seed: u64, jitter_eps: f64) -> Self {
        ComputeScale {
            factor,
            arch_factor,
            rng: SimRng::new(seed, "compute-scale"),
            jitter_eps,
        }
    }

    /// Identity scaling (tests).
    pub fn none() -> Self {
        Self::new(1.0, 1.0, 0, 0.0)
    }

    /// Public alias of `apply` for modeled fast paths that charge
    /// precomputed costs without the `Exec::call` indirection.
    pub fn apply_pub(&mut self, d: Duration) -> Duration {
        self.apply(d)
    }

    fn apply(&mut self, d: Duration) -> Duration {
        let j = if self.jitter_eps > 0.0 {
            self.rng.jitter(self.jitter_eps)
        } else {
            1.0
        };
        d.scale(self.factor * self.arch_factor * j)
    }
}

impl<'a> Exec<'a> {
    /// Execute `entry` as rank `rank`'s work: advance its clock, return
    /// outputs in `Real` mode (`None` in `Modeled`).
    pub fn call(
        &mut self,
        comm: &mut Comm,
        scale: &mut ComputeScale,
        rank: usize,
        entry: &str,
        inputs: &[TensorBuf],
    ) -> Result<Option<Vec<TensorBuf>>> {
        match self {
            Exec::Real { engine } => {
                let t0 = Instant::now();
                let out = engine.execute(entry, inputs)?;
                let wall = Duration::from_secs_f64(t0.elapsed().as_secs_f64());
                comm.advance(rank, scale.apply(wall));
                Ok(Some(out))
            }
            Exec::Modeled { table } => {
                let cost = table.cost(entry);
                comm.advance(rank, scale.apply(cost));
                Ok(None)
            }
        }
    }

    /// Charge rank-local non-kernel work (mesh bookkeeping, etc.).
    pub fn charge(&mut self, comm: &mut Comm, scale: &mut ComputeScale, rank: usize, d: Duration) {
        comm.advance(rank, scale.apply(d));
    }

    /// Charge a whole rank class at once: O(1) on a class-batched
    /// communicator instead of one `charge` per member. One jitter draw
    /// covers the class — in a modeled phase the members execute the
    /// same kernel on identically-shaped blocks, so they share the
    /// run-level perturbation.
    pub fn charge_class(
        &mut self,
        comm: &mut Comm,
        scale: &mut ComputeScale,
        class: usize,
        d: Duration,
    ) {
        comm.advance_class(class, scale.apply(d));
    }

    /// Charge every rank the same compute segment (the modeled solvers'
    /// per-iteration kernels): O(classes) on a batched communicator,
    /// O(ranks) otherwise, with a single jitter draw either way — which
    /// is what keeps the two paths `VirtualTime`-identical.
    pub fn charge_uniform(&mut self, comm: &mut Comm, scale: &mut ComputeScale, d: Duration) {
        comm.advance_uniform(scale.apply(d));
    }

    /// The calibrated cost of `entry` in `Modeled` mode (`None` when
    /// running real PJRT — costs are measured, not looked up).
    pub fn modeled_cost(&self, entry: &str) -> Option<Duration> {
        match self {
            Exec::Real { .. } => None,
            Exec::Modeled { table } => Some(table.cost(entry)),
        }
    }

    /// Whether this is the real (PJRT-executing) mode.
    pub fn is_real(&self) -> bool {
        matches!(self, Exec::Real { .. })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{launch, MachineSpec};
    use crate::net::{Fabric, FabricKind};

    fn comm(ranks: usize) -> Comm {
        Comm::new(
            launch(&MachineSpec::workstation(), ranks).unwrap(),
            Fabric::by_kind(FabricKind::SharedMem),
        )
    }

    #[test]
    fn modeled_charges_table_cost() {
        let table = CalibrationTable::builtin_fallback();
        let mut exec = Exec::Modeled { table: &table };
        let mut scale = ComputeScale::none();
        let mut c = comm(2);
        exec.call(&mut c, &mut scale, 0, "dot_L4096", &[]).unwrap();
        assert_eq!(c.clock(0), crate::des::VirtualTime::ZERO + table.cost("dot_L4096"));
        assert_eq!(c.clock(1), crate::des::VirtualTime::ZERO);
    }

    #[test]
    fn scale_factor_multiplies() {
        let table = CalibrationTable::builtin_fallback();
        let mut exec = Exec::Modeled { table: &table };
        let mut scale = ComputeScale::new(1.15, 1.0, 0, 0.0);
        let mut c = comm(1);
        exec.call(&mut c, &mut scale, 0, "dot_L4096", &[]).unwrap();
        let want = table.cost("dot_L4096").scale(1.15);
        assert_eq!(c.clock(0).since(crate::des::VirtualTime::ZERO), want);
    }

    #[test]
    fn arch_factor_applies() {
        let table = CalibrationTable::builtin_fallback();
        let mut a = comm(1);
        let mut b = comm(1);
        Exec::Modeled { table: &table }
            .call(&mut a, &mut ComputeScale::new(1.0, 1.03, 0, 0.0), 0, "smooth3d_n32", &[])
            .unwrap();
        Exec::Modeled { table: &table }
            .call(&mut b, &mut ComputeScale::none(), 0, "smooth3d_n32", &[])
            .unwrap();
        assert!(a.clock(0) > b.clock(0));
    }

    #[test]
    fn jitter_varies_but_brackets() {
        let table = CalibrationTable::builtin_fallback();
        let base = table.cost("smooth3d_n32").as_secs_f64();
        let mut scale = ComputeScale::new(1.0, 1.0, 7, 0.05);
        let mut c = comm(1);
        let mut exec = Exec::Modeled { table: &table };
        for _ in 0..50 {
            exec.call(&mut c, &mut scale, 0, "smooth3d_n32", &[]).unwrap();
        }
        let total = c.clock(0).as_secs_f64();
        assert!((total - 50.0 * base).abs() < 50.0 * base * 0.05);
        assert!(total != 50.0 * base, "jitter should not be exactly zero");
    }

    #[test]
    fn charge_uniform_single_draw_matches_everywhere() {
        let table = CalibrationTable::builtin_fallback();
        let mut exec = Exec::Modeled { table: &table };
        let cost = table.cost("dot_L4096");
        // jittered: both ranks must still receive the identical charge
        let mut scale = ComputeScale::new(1.0, 1.0, 3, 0.05);
        let mut c = comm(2);
        exec.charge_uniform(&mut c, &mut scale, cost);
        assert_eq!(c.clock(0), c.clock(1));
        assert!(c.clock(0).as_secs_f64() > 0.0);
        assert_eq!(exec.modeled_cost("dot_L4096"), Some(cost));
    }

    #[test]
    fn charge_class_targets_members() {
        use crate::fem::grid::Decomp;
        let table = CalibrationTable::builtin_fallback();
        let mut exec = Exec::Modeled { table: &table };
        let mut scale = ComputeScale::none();
        let decomp = Decomp::new(8, 16);
        let mut c = Comm::new(
            crate::cluster::launch(&crate::cluster::MachineSpec::edison(), 8).unwrap(),
            Fabric::by_kind(FabricKind::Aries),
        );
        let classes = decomp.rank_classes(c.allocation());
        let target = classes.class_of(0) as usize;
        c.set_classes(classes.clone());
        exec.charge_class(&mut c, &mut scale, target, Duration::from_millis(1));
        for r in 0..8 {
            let expect = if classes.class_of(r) as usize == target { 0.001 } else { 0.0 };
            assert_eq!(c.clock(r).as_secs_f64(), expect, "rank {r}");
        }
    }

    #[test]
    fn real_mode_produces_data_and_time() {
        if !crate::runtime::artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let mut engine = Engine::open_default().unwrap();
        let mut exec = Exec::Real { engine: &mut engine };
        let mut scale = ComputeScale::none();
        let mut c = comm(1);
        let a = TensorBuf::new(vec![4096], vec![1.0; 4096]);
        let out = exec
            .call(&mut c, &mut scale, 0, "dot_L4096", &[a.clone(), a])
            .unwrap()
            .unwrap();
        assert!((out[0].data[0] - 4096.0).abs() < 1.0);
        assert!(c.clock(0).as_secs_f64() > 0.0);
        assert!(exec.is_real());
    }
}
