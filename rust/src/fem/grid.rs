//! Domain decomposition and halo exchange.
//!
//! The global grid is `dims[d] * n_local` cells per axis, split into one
//! `n_local`³ block per rank (matching the shapes the AOT artifacts were
//! exported at).  Fields are stored halo-padded, `(n+2)`³, with zero
//! halos at physical (Dirichlet) boundaries and neighbour data after an
//! exchange.

use std::collections::HashMap;

use crate::cluster::Allocation;
use crate::mpi::{Comm, HaloPattern, RankClasses};

/// Ascending divisors of `p`, found by trial division up to √p.
fn divisors(p: usize) -> Vec<usize> {
    let mut low = Vec::new();
    let mut high = Vec::new();
    let mut a = 1;
    while a * a <= p {
        if p % a == 0 {
            low.push(a);
            if a != p / a {
                high.push(p / a);
            }
        }
        a += 1;
    }
    low.extend(high.into_iter().rev());
    low
}

/// Near-cubic factorisation of `p` into three factors (descending
/// products keep slabs compact): used to build the process grid.
///
/// PERF: iterates only the divisors of `p` (O(√p + d(p)²)) instead of
/// scanning `1..=p` per level — at the 98304-rank scale points the old
/// scan was ~3000× more candidate pairs (EXPERIMENTS.md §Perf). The
/// ascending iteration order matches the old scan, so ties resolve to
/// the same factorisation.
pub fn factor3(p: usize) -> [usize; 3] {
    assert!(p > 0);
    let mut best = [p, 1, 1];
    let mut best_score = usize::MAX;
    let divs = divisors(p);
    for &a in &divs {
        let q = p / a;
        for &b in &divs {
            if b > q || q % b != 0 {
                continue;
            }
            let c = q / b;
            // surface-area proxy: sum of pairwise products (lower = more cubic)
            let score = a * b + b * c + a * c;
            if score < best_score {
                best_score = score;
                let mut f = [a, b, c];
                f.sort_unstable();
                best = f;
            }
        }
    }
    best
}

/// Face directions: `-z, +z, -y, +y, -x, +x`.
pub const DIRS: usize = 6;

/// 3D Cartesian decomposition of `ranks` blocks of `n_local`³ cells.
#[derive(Debug, Clone)]
pub struct Decomp {
    /// Per-rank block edge (each rank owns `n_local`³ cells).
    pub n_local: usize,
    /// Process-grid extents `[pz, py, px]`.
    pub dims: [usize; 3],
    /// Rank -> process-grid coordinates `[z, y, x]`.
    pub coords: Vec<[usize; 3]>,
}

impl Decomp {
    /// Decompose `ranks` blocks of `n_local`³ cells onto the most
    /// cubic process grid `factor3` finds.
    pub fn new(ranks: usize, n_local: usize) -> Self {
        let dims = factor3(ranks);
        let coords = (0..ranks)
            .map(|r| {
                let z = r / (dims[1] * dims[2]);
                let y = (r / dims[2]) % dims[1];
                let x = r % dims[2];
                [z, y, x]
            })
            .collect();
        Decomp {
            n_local,
            dims,
            coords,
        }
    }

    /// Number of ranks in the decomposition.
    pub fn ranks(&self) -> usize {
        self.coords.len()
    }

    /// Global grid extents `[nz, ny, nx]`.
    pub fn n_global(&self) -> [usize; 3] {
        [
            self.dims[0] * self.n_local,
            self.dims[1] * self.n_local,
            self.dims[2] * self.n_local,
        ]
    }

    /// Total degrees of freedom (scalar field).
    pub fn dofs(&self) -> u64 {
        self.n_global().iter().map(|&n| n as u64).product()
    }

    /// Rank at process coordinates, if inside the grid.
    pub fn rank_at(&self, c: [isize; 3]) -> Option<usize> {
        for d in 0..3 {
            if c[d] < 0 || c[d] >= self.dims[d] as isize {
                return None;
            }
        }
        Some(
            (c[0] as usize * self.dims[1] + c[1] as usize) * self.dims[2] + c[2] as usize,
        )
    }

    /// The 6 face neighbours of `rank` (None at physical boundaries),
    /// in [`DIRS`] order.
    pub fn neighbors(&self, rank: usize) -> [Option<usize>; DIRS] {
        let c = self.coords[rank];
        let ci = [c[0] as isize, c[1] as isize, c[2] as isize];
        [
            self.rank_at([ci[0] - 1, ci[1], ci[2]]),
            self.rank_at([ci[0] + 1, ci[1], ci[2]]),
            self.rank_at([ci[0], ci[1] - 1, ci[2]]),
            self.rank_at([ci[0], ci[1] + 1, ci[2]]),
            self.rank_at([ci[0], ci[1], ci[2] - 1]),
            self.rank_at([ci[0], ci[1], ci[2] + 1]),
        ]
    }

    /// Global index of the first interior cell of `rank` (`[iz, iy, ix]`).
    pub fn origin(&self, rank: usize) -> [usize; 3] {
        let c = self.coords[rank];
        [
            c[0] * self.n_local,
            c[1] * self.n_local,
            c[2] * self.n_local,
        ]
    }

    /// The halo-exchange message list: one message per shared face,
    /// `bytes_per_face` each (what the simulated MPI charges).
    pub fn halo_messages(&self, bytes_per_face: u64) -> Vec<(usize, usize, u64)> {
        let mut msgs = Vec::new();
        for r in 0..self.ranks() {
            for nb in self.neighbors(r).into_iter().flatten() {
                msgs.push((r, nb, bytes_per_face));
            }
        }
        msgs
    }

    /// Face payload in bytes for a scalar f32 field at this block size.
    pub fn face_bytes(&self) -> u64 {
        (self.n_local * self.n_local * 4) as u64
    }

    /// Off-node halo message count per node under `alloc` (the quantity
    /// that sizes each node's NIC serialisation in a uniform phase).
    fn offnode_msgs(&self, alloc: &Allocation) -> Vec<u32> {
        let mut off = vec![0u32; alloc.nodes_used];
        for r in 0..self.ranks() {
            for nb in self.neighbors(r).into_iter().flatten() {
                if !alloc.same_node(r, nb) {
                    off[alloc.node_of[r]] += 1;
                }
            }
        }
        off
    }

    /// The one-hop halo signature of `rank`: per direction, `None` at a
    /// physical boundary, else `(same_node, neighbour_node_offnode_msgs)`.
    fn halo_key(
        &self,
        alloc: &Allocation,
        off: &[u32],
        rank: usize,
    ) -> [Option<(bool, u32)>; DIRS] {
        self.neighbors(rank)
            .map(|nb| nb.map(|nb| (alloc.same_node(rank, nb), off[alloc.node_of[nb]])))
    }

    /// Group ranks into equivalence classes by halo-neighbour signature:
    /// which faces are shared (interior / face / edge / corner of the
    /// process grid), whether each neighbour sits on the same node, and
    /// how many off-node messages the neighbour's node injects. Two
    /// ranks in one class advance identically through any uniform halo
    /// phase entered from a globally uniform clock state — the invariant
    /// `Comm::exchange_uniform` batches on. Class counts stay small
    /// (~dozens to a few hundred) even at 98304 ranks, where the rank
    /// count is ~300× larger (EXPERIMENTS.md §Perf).
    pub fn rank_classes(&self, alloc: &Allocation) -> RankClasses {
        assert_eq!(
            alloc.ranks(),
            self.ranks(),
            "allocation has {} ranks, decomposition {}",
            alloc.ranks(),
            self.ranks()
        );
        let off = self.offnode_msgs(alloc);
        let mut ids: HashMap<[Option<(bool, u32)>; DIRS], u32> = HashMap::new();
        let mut class_of = Vec::with_capacity(self.ranks());
        for r in 0..self.ranks() {
            let key = self.halo_key(alloc, &off, r);
            let next = ids.len() as u32;
            class_of.push(*ids.entry(key).or_insert(next));
        }
        RankClasses::new(class_of)
    }

    /// Pre-compile the uniform halo phase of `bytes_per_face` against a
    /// rank partition: per-class incoming edges for the O(classes)
    /// batched update plus the flat message list for the per-rank
    /// fallback. The partition must come from `rank_classes` on this
    /// decomposition (same topology; `n_local` may differ, as on the
    /// multigrid ladder).
    pub fn halo_pattern(
        &self,
        alloc: &Allocation,
        classes: &RankClasses,
        bytes_per_face: u64,
    ) -> HaloPattern {
        assert_eq!(classes.ranks(), self.ranks());
        let off = self.offnode_msgs(alloc);
        let class_edges = (0..classes.len())
            .map(|c| {
                let rep = classes.representative(c);
                self.halo_key(alloc, &off, rep)
                    .into_iter()
                    .flatten()
                    .collect()
            })
            .collect();
        HaloPattern {
            bytes: bytes_per_face,
            class_edges,
            messages: self.halo_messages(bytes_per_face),
        }
    }

    /// As [`halo_pattern`](Self::halo_pattern), taking the partition from
    /// `comm` (empty batched side when none is installed, so the pattern
    /// degenerates to its per-rank message list).
    pub fn halo_pattern_for(&self, comm: &Comm, bytes_per_face: u64) -> HaloPattern {
        match comm.classes() {
            Some(classes) if classes.ranks() == self.ranks() => {
                self.halo_pattern(comm.allocation(), classes, bytes_per_face)
            }
            _ => HaloPattern {
                bytes: bytes_per_face,
                class_edges: Vec::new(),
                messages: self.halo_messages(bytes_per_face),
            },
        }
    }
}

/// A halo-padded scalar field on one rank: `(n+2)`³ f32, row-major
/// `(z, y, x)`.
#[derive(Debug, Clone, PartialEq)]
pub struct LocalField {
    /// Interior edge length (storage adds a one-cell halo shell).
    pub n: usize,
    /// `(n+2)`³ values in z-major order.
    pub data: Vec<f32>,
}

impl LocalField {
    /// A zero field with halo storage for an `n`³ interior.
    pub fn zeros(n: usize) -> Self {
        LocalField {
            n,
            data: vec![0.0; (n + 2) * (n + 2) * (n + 2)],
        }
    }

    /// Build from interior values (halo zeroed).
    pub fn from_interior(n: usize, interior: &[f32]) -> Self {
        assert_eq!(interior.len(), n * n * n);
        let mut f = Self::zeros(n);
        for z in 0..n {
            for y in 0..n {
                let src = (z * n + y) * n;
                let dst = f.idx(z + 1, y + 1, 1);
                f.data[dst..dst + n].copy_from_slice(&interior[src..src + n]);
            }
        }
        f
    }

    #[inline]
    /// Flat index of `(z, y, x)` in halo-padded storage.
    pub fn idx(&self, z: usize, y: usize, x: usize) -> usize {
        let np = self.n + 2;
        (z * np + y) * np + x
    }

    /// Copy the interior out (row-major n³).
    pub fn interior(&self) -> Vec<f32> {
        let n = self.n;
        let mut out = vec![0.0; n * n * n];
        for z in 0..n {
            for y in 0..n {
                let src = self.idx(z + 1, y + 1, 1);
                let dst = (z * n + y) * n;
                out[dst..dst + n].copy_from_slice(&self.data[src..src + n]);
            }
        }
        out
    }

    /// Extract the interior face plane adjacent to direction `dir`
    /// (what gets *sent* to the neighbour in that direction).
    pub fn face(&self, dir: usize) -> Vec<f32> {
        let n = self.n;
        let mut out = Vec::with_capacity(n * n);
        for a in 0..n {
            for b in 0..n {
                let (z, y, x) = face_coords(dir, 0, a, b, n);
                out.push(self.data[self.idx(z, y, x)]);
            }
        }
        out
    }

    /// Write a received neighbour plane into the halo for direction `dir`.
    pub fn set_halo(&mut self, dir: usize, plane: &[f32]) {
        let n = self.n;
        assert_eq!(plane.len(), n * n);
        let mut it = plane.iter();
        for a in 0..n {
            for b in 0..n {
                let (z, y, x) = face_coords(dir, 1, a, b, n);
                let i = self.idx(z, y, x);
                self.data[i] = *it.next().unwrap();
            }
        }
    }

    /// Zero the halo plane for direction `dir` (physical boundary).
    pub fn zero_halo(&mut self, dir: usize) {
        let n = self.n;
        let zeros = vec![0.0; n * n];
        self.set_halo(dir, &zeros);
    }
}

/// Coordinates of the (a, b)-th cell of a face plane.
/// `halo = 0`: the interior plane adjacent to `dir` (send side);
/// `halo = 1`: the halo plane in direction `dir` (receive side).
fn face_coords(dir: usize, halo: usize, a: usize, b: usize, n: usize) -> (usize, usize, usize) {
    let lo_int = 1; // first interior index (padded coords)
    let hi_int = n; // last interior index
    let lo_halo = 0;
    let hi_halo = n + 1;
    match (dir, halo) {
        (0, 0) => (lo_int, a + 1, b + 1),  // send toward -z
        (0, 1) => (lo_halo, a + 1, b + 1), // receive from -z
        (1, 0) => (hi_int, a + 1, b + 1),
        (1, 1) => (hi_halo, a + 1, b + 1),
        (2, 0) => (a + 1, lo_int, b + 1),
        (2, 1) => (a + 1, lo_halo, b + 1),
        (3, 0) => (a + 1, hi_int, b + 1),
        (3, 1) => (a + 1, hi_halo, b + 1),
        (4, 0) => (a + 1, b + 1, lo_int),
        (4, 1) => (a + 1, b + 1, lo_halo),
        (5, 0) => (a + 1, b + 1, hi_int),
        (5, 1) => (a + 1, b + 1, hi_halo),
        _ => unreachable!("dir < 6, halo < 2"),
    }
}

/// Opposite direction (`-z <-> +z`, ...).
pub fn opposite(dir: usize) -> usize {
    dir ^ 1
}

/// Extract/insert a full-width boundary plane for the dimension-ordered
/// exchange. `axis` is the exchange axis; `lo` selects the low/high side;
/// `halo` selects the interior plane (send side, 0) or the halo plane
/// (receive side, 1). Axes *before* `axis` span their full padded width
/// (their halos were exchanged in earlier phases, so edge/corner ghosts
/// ride along); axes after span the interior only.
fn plane_range(axis: usize, n: usize) -> impl Fn(usize) -> (usize, usize) {
    move |other_axis: usize| {
        if other_axis < axis {
            (0, n + 2) // full padded width: earlier-phase halos included
        } else {
            (1, n + 1) // interior only
        }
    }
}

impl LocalField {
    fn plane(&self, axis: usize, lo: bool, halo: bool) -> Vec<f32> {
        let n = self.n;
        let fixed = match (lo, halo) {
            (true, false) => 1,      // interior plane adjacent to low side
            (true, true) => 0,       // low halo plane
            (false, false) => n,     // interior plane adjacent to high side
            (false, true) => n + 1,  // high halo plane
        };
        let range = plane_range(axis, n);
        let mut out = Vec::new();
        let axes: Vec<usize> = (0..3).filter(|&a| a != axis).collect();
        let (a0, a1) = (axes[0], axes[1]);
        let (s0, e0) = range(a0);
        let (s1, e1) = range(a1);
        for i in s0..e0 {
            for j in s1..e1 {
                let mut c = [0usize; 3];
                c[axis] = fixed;
                c[a0] = i;
                c[a1] = j;
                out.push(self.data[self.idx(c[0], c[1], c[2])]);
            }
        }
        out
    }

    fn set_plane(&mut self, axis: usize, lo: bool, plane: &[f32]) {
        let n = self.n;
        let fixed = if lo { 0 } else { n + 1 };
        let range = plane_range(axis, n);
        let axes: Vec<usize> = (0..3).filter(|&a| a != axis).collect();
        let (a0, a1) = (axes[0], axes[1]);
        let (s0, e0) = range(a0);
        let (s1, e1) = range(a1);
        let mut it = plane.iter();
        for i in s0..e0 {
            for j in s1..e1 {
                let mut c = [0usize; 3];
                c[axis] = fixed;
                c[a0] = i;
                c[a1] = j;
                let idx = self.idx(c[0], c[1], c[2]);
                self.data[idx] = *it.next().unwrap();
            }
        }
    }
}

/// Exchange halos for one scalar field per rank: moves real data between
/// the per-rank arrays *and* charges the communication to `comm`.
///
/// Dimension-ordered (z, then y, then x), with each later phase sending
/// full-width planes that include the earlier phases' halos — so edge and
/// corner ghosts are filled correctly (the standard 26-neighbour
/// exchange via 6 messages). Physical boundaries hold zeros.
pub fn exchange_halos(decomp: &Decomp, fields: &mut [LocalField], comm: &mut Comm) {
    assert_eq!(fields.len(), decomp.ranks());
    // physical boundaries (and stale edge/corner ghosts) zeroed first
    for f in fields.iter_mut() {
        let n = f.n;
        let np = n + 2;
        for z in 0..np {
            for y in 0..np {
                for x in 0..np {
                    if z == 0 || z == np - 1 || y == 0 || y == np - 1 || x == 0 || x == np - 1 {
                        let i = f.idx(z, y, x);
                        f.data[i] = 0.0;
                    }
                }
            }
        }
    }
    for axis in 0..3 {
        let mut incoming: Vec<(usize, bool, Vec<f32>)> = Vec::new();
        for r in 0..decomp.ranks() {
            let nbs = decomp.neighbors(r);
            for (side_lo, dir) in [(true, 2 * axis), (false, 2 * axis + 1)] {
                if let Some(nb) = nbs[dir] {
                    // my plane toward `dir` lands in nb's opposite halo
                    incoming.push((nb, !side_lo, fields[r].plane(axis, side_lo, false)));
                }
            }
        }
        for (nb, lo, plane) in incoming {
            fields[nb].set_plane(axis, lo, &plane);
        }
    }
    // timing: one message per shared face (payload ~ n² + ring)
    comm.exchange(&decomp.halo_messages(decomp.face_bytes()));
}

/// Timing-only halo exchange (Modeled execution): class-batched when the
/// communicator carries a partition, per-rank messages otherwise.
pub fn exchange_halos_modeled(decomp: &Decomp, comm: &mut Comm, bytes_per_face: u64) {
    if comm.is_batched() {
        let pattern = decomp.halo_pattern_for(comm, bytes_per_face);
        comm.exchange_uniform(&pattern);
    } else {
        comm.exchange(&decomp.halo_messages(bytes_per_face));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{launch, MachineSpec};
    use crate::net::{Fabric, FabricKind};

    #[test]
    fn factor3_near_cubic() {
        assert_eq!(factor3(8), [2, 2, 2]);
        assert_eq!(factor3(24), [2, 3, 4]);
        assert_eq!(factor3(27), [3, 3, 3]);
        assert_eq!(factor3(1), [1, 1, 1]);
        assert_eq!(factor3(192).iter().product::<usize>(), 192);
        let f = factor3(192);
        assert!(f[2] <= 8, "192 should split compactly: {f:?}");
    }

    #[test]
    fn decomp_coords_round_trip() {
        let d = Decomp::new(24, 16);
        assert_eq!(d.dims.iter().product::<usize>(), 24);
        for r in 0..24 {
            let c = d.coords[r];
            assert_eq!(
                d.rank_at([c[0] as isize, c[1] as isize, c[2] as isize]),
                Some(r)
            );
        }
        assert_eq!(d.dofs(), (d.n_global().iter().product::<usize>()) as u64);
    }

    #[test]
    fn neighbors_are_mutual() {
        let d = Decomp::new(27, 8);
        for r in 0..27 {
            for (dir, nb) in d.neighbors(r).into_iter().enumerate() {
                if let Some(nb) = nb {
                    assert_eq!(
                        d.neighbors(nb)[opposite(dir)],
                        Some(r),
                        "rank {r} dir {dir}"
                    );
                }
            }
        }
    }

    #[test]
    fn boundary_ranks_have_no_outside_neighbors() {
        let d = Decomp::new(8, 4); // 2x2x2
        let nb = d.neighbors(0); // corner block
        assert_eq!(nb.iter().flatten().count(), 3);
    }

    #[test]
    fn field_interior_round_trip() {
        let n = 4;
        let interior: Vec<f32> = (0..n * n * n).map(|i| i as f32).collect();
        let f = LocalField::from_interior(n, &interior);
        assert_eq!(f.interior(), interior);
        // halo is zero
        assert_eq!(f.data[f.idx(0, 2, 2)], 0.0);
        assert_eq!(f.data[f.idx(n + 1, 2, 2)], 0.0);
    }

    #[test]
    fn face_and_set_halo_are_consistent() {
        // sending my +x face to a neighbour and writing it into their -x
        // halo must preserve (a, b) orientation
        let n = 3;
        let mut a = LocalField::zeros(n);
        for z in 0..n {
            for y in 0..n {
                for x in 0..n {
                    let i = a.idx(z + 1, y + 1, x + 1);
                    a.data[i] = (100 * z + 10 * y + x) as f32;
                }
            }
        }
        let face = a.face(5); // +x interior plane
        let mut b = LocalField::zeros(n);
        b.set_halo(4, &face); // neighbour's -x halo
        for z in 0..n {
            for y in 0..n {
                assert_eq!(
                    b.data[b.idx(z + 1, y + 1, 0)],
                    (100 * z + 10 * y + (n - 1)) as f32,
                    "z={z} y={y}"
                );
            }
        }
    }

    #[test]
    fn exchange_stitches_a_global_ramp() {
        // 2 ranks along z; field = global z index. After the exchange,
        // rank 0's +z halo must hold rank 1's first plane and vice versa.
        let d = Decomp::new(2, 4);
        assert_eq!(d.dims, [1, 1, 2]); // sorted ascending -> split along x
        let n = 4;
        let mut fields: Vec<LocalField> = (0..2)
            .map(|r| {
                let origin = d.origin(r);
                let interior: Vec<f32> = (0..n * n * n)
                    .map(|i| {
                        let x = i % n;
                        (origin[2] + x) as f32
                    })
                    .collect();
                LocalField::from_interior(n, &interior)
            })
            .collect();
        let m = MachineSpec::workstation();
        let mut comm = Comm::new(launch(&m, 2).unwrap(), Fabric::by_kind(FabricKind::SharedMem));
        exchange_halos(&d, &mut fields, &mut comm);
        // rank 0 (+x halo) sees rank 1's first x-plane (global x = 4)
        let f0 = &fields[0];
        assert_eq!(f0.data[f0.idx(2, 2, n + 1)], 4.0);
        // rank 1 (-x halo) sees rank 0's last x-plane (global x = 3)
        let f1 = &fields[1];
        assert_eq!(f1.data[f1.idx(2, 2, 0)], 3.0);
        // physical boundaries stay zero
        assert_eq!(f0.data[f0.idx(2, 2, 0)], 0.0);
        // and the exchange was charged
        assert!(comm.stats().p2p_messages == 2);
        assert!(comm.max_clock().as_secs_f64() > 0.0);
    }

    #[test]
    fn halo_message_list_counts_shared_faces() {
        let d = Decomp::new(8, 4); // 2x2x2: 12 shared faces, 2 msgs each
        let msgs = d.halo_messages(64);
        assert_eq!(msgs.len(), 24);
        assert!(msgs.iter().all(|&(_, _, b)| b == 64));
    }

    #[test]
    fn factor3_fast_at_scale_points() {
        // the divisor-only iteration must stay exact at the Fig 3/4
        // scale points (and be fast enough to call in a test at all)
        assert_eq!(factor3(1536), [8, 12, 16]);
        assert_eq!(factor3(12288), [16, 24, 32]);
        assert_eq!(factor3(98304), [32, 48, 64]);
        assert_eq!(factor3(97), [1, 1, 97]); // prime
        for p in 1..=256 {
            assert_eq!(factor3(p).iter().product::<usize>(), p);
        }
    }

    #[test]
    fn rank_classes_partition_is_consistent() {
        let m = MachineSpec::edison();
        for ranks in [1usize, 2, 24, 96, 192] {
            let d = Decomp::new(ranks, 8);
            let alloc = launch(&m, ranks).unwrap();
            let classes = d.rank_classes(&alloc);
            assert_eq!(classes.ranks(), ranks);
            let total: u32 = (0..classes.len()).map(|c| classes.count(c)).sum();
            assert_eq!(total as usize, ranks);
            for c in 0..classes.len() {
                let rep = classes.representative(c);
                assert_eq!(classes.class_of(rep) as usize, c);
            }
            assert!(classes.len() <= ranks);
        }
    }

    #[test]
    fn rank_classes_collapse_at_scale() {
        // the whole point: class counts stay ~constant while rank counts
        // explode (measured in EXPERIMENTS.md §Perf)
        let m = MachineSpec::edison();
        let d = Decomp::new(1536, 8);
        let alloc = launch(&m, 1536).unwrap();
        let classes = d.rank_classes(&alloc);
        assert!(
            classes.len() < 1536 / 4,
            "expected heavy collapse, got {} classes",
            classes.len()
        );
    }

    #[test]
    fn halo_pattern_edges_match_representatives() {
        let m = MachineSpec::edison();
        let d = Decomp::new(48, 8);
        let alloc = launch(&m, 48).unwrap();
        let classes = d.rank_classes(&alloc);
        let pat = d.halo_pattern(&alloc, &classes, d.face_bytes());
        assert_eq!(pat.class_edges.len(), classes.len());
        assert_eq!(pat.messages, d.halo_messages(d.face_bytes()));
        for c in 0..classes.len() {
            let rep = classes.representative(c);
            let shared = d.neighbors(rep).iter().flatten().count();
            assert_eq!(pat.class_edges[c].len(), shared, "class {c}");
        }
        assert_eq!(pat.total_bytes(), pat.messages.len() as u64 * d.face_bytes());
    }
}
