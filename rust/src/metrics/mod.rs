//! Phase timing and statistics.
//!
//! The paper reports every experiment as a per-phase breakdown (assemble /
//! refine / solve / IO / import) with error bars over repeated runs.
//! [`PhaseBreakdown`] accumulates virtual-time spans per named phase for
//! one run; [`Stats`] aggregates repetitions into mean / std / min / max —
//! the numbers the figures plot.

use std::collections::BTreeMap;


use crate::des::Duration;

/// Per-phase virtual-time totals for a single run.
#[derive(Debug, Clone, Default)]
pub struct PhaseBreakdown {
    /// Phase name -> accumulated duration. BTreeMap for stable ordering.
    phases: BTreeMap<String, Duration2>,
    /// Insertion order of first occurrence (presentation order).
    order: Vec<String>,
}

/// Serializable mirror of `des::Duration` (seconds as f64 on the wire).
#[derive(Debug, Clone, Copy, Default)]
pub struct Duration2 {
    secs: f64,
}

impl From<Duration> for Duration2 {
    fn from(d: Duration) -> Self {
        Duration2 {
            secs: d.as_secs_f64(),
        }
    }
}

impl Duration2 {
    /// The span in (fractional) seconds.
    pub fn as_secs_f64(self) -> f64 {
        self.secs
    }
}

impl PhaseBreakdown {
    /// An empty breakdown.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `d` to phase `name` (creating it on first use).
    pub fn add(&mut self, name: &str, d: Duration) {
        if !self.phases.contains_key(name) {
            self.order.push(name.to_string());
        }
        let e = self.phases.entry(name.to_string()).or_default();
        e.secs += d.as_secs_f64();
    }

    /// Seconds recorded for `name` (0.0 if absent).
    pub fn get(&self, name: &str) -> f64 {
        self.phases.get(name).map(|d| d.secs).unwrap_or(0.0)
    }

    /// Total across phases, in seconds.
    pub fn total(&self) -> f64 {
        self.phases.values().map(|d| d.secs).sum()
    }

    /// Phases in first-recorded order.
    pub fn phase_names(&self) -> &[String] {
        &self.order
    }

    /// Whether no phase has been recorded.
    pub fn is_empty(&self) -> bool {
        self.phases.is_empty()
    }

    /// Machine-readable form: `{phase: seconds}`.
    pub fn to_json(&self) -> crate::util::json::Value {
        use crate::util::json::Value;
        Value::Obj(
            self.phases
                .iter()
                .map(|(k, v)| (k.clone(), Value::Num(v.secs)))
                .collect(),
        )
    }
}

/// Aggregate of repeated scalar measurements (seconds, DOF/s, ...).
#[derive(Debug, Clone)]
pub struct Stats {
    /// The raw samples, in measurement order.
    pub samples: Vec<f64>,
}

impl Stats {
    /// Wrap samples (must be non-empty).
    pub fn from_samples(samples: Vec<f64>) -> Self {
        assert!(!samples.is_empty(), "stats over zero samples");
        Stats { samples }
    }

    /// Arithmetic mean.
    pub fn mean(&self) -> f64 {
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    /// Sample standard deviation (n-1); 0 for a single sample.
    pub fn std(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.samples.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (n - 1) as f64).sqrt()
    }

    /// Smallest sample.
    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    /// Largest sample.
    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    /// Number of samples.
    pub fn n(&self) -> usize {
        self.samples.len()
    }

    /// Coefficient of variation (std / mean); the paper's "variability".
    pub fn cv(&self) -> f64 {
        let m = self.mean();
        if m == 0.0 {
            0.0
        } else {
            self.std() / m
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_accumulates_and_orders() {
        let mut b = PhaseBreakdown::new();
        b.add("solve", Duration::from_millis(100));
        b.add("assemble", Duration::from_millis(50));
        b.add("solve", Duration::from_millis(25));
        assert_eq!(b.get("solve"), 0.125);
        assert_eq!(b.get("assemble"), 0.050);
        assert_eq!(b.get("missing"), 0.0);
        assert!((b.total() - 0.175).abs() < 1e-12);
        assert_eq!(b.phase_names(), &["solve".to_string(), "assemble".to_string()]);
    }

    #[test]
    fn stats_basics() {
        let s = Stats::from_samples(vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.mean(), 2.5);
        assert!((s.std() - 1.2909944487).abs() < 1e-9);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert_eq!(s.n(), 4);
    }

    #[test]
    fn single_sample_has_zero_std() {
        let s = Stats::from_samples(vec![3.0]);
        assert_eq!(s.std(), 0.0);
        assert_eq!(s.cv(), 0.0);
    }

    #[test]
    fn breakdown_serializes() {
        let mut b = PhaseBreakdown::new();
        b.add("io", Duration::from_millis(7));
        let j = b.to_json().to_string();
        let v = crate::util::json::parse(&j).unwrap();
        assert_eq!(v.get("io").as_f64(), Some(0.007));
    }

    #[test]
    #[should_panic]
    fn empty_stats_panics() {
        Stats::from_samples(vec![]);
    }
}
