//! The Edison test program (Figs 3 and 4).
//!
//! "A simple FEniCS test program which solves the Poisson equation using
//! the conjugate gradient method [...] and which also involves
//! distributed mesh refinement and I/O" (§4.2).  Phases:
//!
//! 1. `import` (Python variant only) — every rank imports the FEniCS
//!    stack through the platform's code filesystem.
//! 2. `assemble` — RHS assembly (AOT kernel) + mesh partitioning.
//! 3. `refine` — distributed mesh refinement: per-cell work + face
//!    exchange + a synchronising reduction.
//! 4. `solve` — distributed CG (the paper's dominant phase).
//! 5. `io` — each rank writes its solution chunk to scratch.
//!
//! Container start-up is charged before phase 1 on containerised
//! platforms (it is part of what `srun shifter ...` pays per rank,
//! though small).

use anyhow::Result;

use crate::cluster::MachineSpec;
use crate::des::{Duration, VirtualTime};
use crate::fem::cg::{distributed_cg, estimate_cg_iters, CgConfig};
use crate::fem::exec::Exec;
use crate::fem::grid::{exchange_halos_modeled, Decomp};
use crate::metrics::PhaseBreakdown;
use crate::platform::Platform;
use crate::pyimport::{replay, replay_batched, ModuleGraph};
use crate::runtime::TensorBuf;
use crate::workload::RunSetup;

/// Configuration of one app run.
#[derive(Debug, Clone)]
pub struct AppConfig {
    /// MPI ranks.
    pub ranks: usize,
    /// Per-rank block edge (16 or 32; the exported shapes).
    pub n_local: usize,
    /// Python driver (adds the import phase) vs C++ driver.
    pub python: bool,
    /// CG relative-residual tolerance.
    pub tol: f64,
    /// Simulation seed.
    pub seed: u64,
    /// Run the modeled phases on the rank-class batched engine
    /// (O(classes) hot paths; `false` forces the per-rank reference
    /// path — the two are VirtualTime-identical except for the
    /// per-burst noise collapse in the batched native import).
    pub batched: bool,
}

impl AppConfig {
    /// The Fig 3 cell: C++ driver, no import phase.
    pub fn cpp(ranks: usize, seed: u64) -> Self {
        AppConfig {
            ranks,
            n_local: 32,
            python: false,
            tol: 1e-5,
            seed,
            batched: true,
        }
    }

    /// The Fig 4 cell: Python driver with the import phase.
    pub fn python(ranks: usize, seed: u64) -> Self {
        AppConfig {
            python: true,
            ..Self::cpp(ranks, seed)
        }
    }

    /// The per-rank reference engine (equivalence tests, perf baselines).
    pub fn per_rank(mut self) -> Self {
        self.batched = false;
        self
    }
}

/// Per-cell refine cost (tree traversal + re-numbering, from profiling
/// DOLFIN-style refinement: ~100 ns/cell).
const REFINE_NS_PER_CELL: u64 = 100;

/// Run the app on Edison under `platform`; returns the phase breakdown
/// (virtual seconds).
pub fn run_poisson_app(
    platform: Platform,
    exec: &mut Exec,
    cfg: &AppConfig,
) -> Result<PhaseBreakdown> {
    let machine = MachineSpec::edison();
    let setup = RunSetup::new(machine.clone(), platform, cfg.ranks, cfg.seed);
    let decomp = Decomp::new(cfg.ranks, cfg.n_local);
    let mut comm = setup.comm();
    let batched = cfg.batched && !exec.is_real();
    if batched {
        // the rank-class engine: every modeled phase below runs in
        // O(classes); per-rank operations (import stagger, IO) fall back
        // transparently and the phase barriers re-engage batching
        comm.set_classes(decomp.rank_classes(comm.allocation()));
    }
    let mut scale = setup.scale(false);
    let mut breakdown = PhaseBreakdown::new();
    let mut phase_start = VirtualTime::ZERO;

    let mut mark = |comm: &mut crate::mpi::Comm, breakdown: &mut PhaseBreakdown, name: &str| {
        comm.barrier();
        let now = comm.max_clock();
        breakdown.add(name, now - phase_start);
        phase_start = now;
    };

    // NB: the paper's timers live *inside* the program (JIT and container
    // start-up excluded, §4.1/§4.2), so container start is not a phase
    // here — `RunSetup::startup()` reports it for the deployment traces.
    let _ = machine;

    // -- import (Python only) ---------------------------------------------
    if cfg.python {
        let graph = ModuleGraph::fenics_stack();
        let mut fs = setup.code_fs();
        let report = if batched {
            replay_batched(&graph, comm.allocation(), fs.as_mut(), comm.max_clock())
        } else {
            replay(&graph, comm.allocation(), fs.as_mut(), comm.max_clock())
        };
        for (r, &done) in report.rank_done.iter().enumerate() {
            comm.advance(r, done.max(comm.clock(r)) - comm.clock(r));
        }
        mark(&mut comm, &mut breakdown, "import");
    }

    // -- assemble ----------------------------------------------------------
    let n = cfg.n_local;
    let h = 1.0 / (decomp.n_global()[0] as f32);
    let mut rhs: Vec<Vec<f32>> = Vec::new();
    let bookkeeping = Duration::from_nanos(40 * (n * n * n) as u64);
    if let Some(assemble_cost) = exec.modeled_cost(&format!("assemble_rhs3d_n{n}")) {
        // modeled: every rank assembles an identically-shaped block —
        // one uniform charge per phase (O(classes) when batched, and a
        // single calibration lookup instead of one per rank)
        exec.charge_uniform(&mut comm, &mut scale, assemble_cost);
        exec.charge_uniform(&mut comm, &mut scale, bookkeeping);
    } else {
        for r in 0..cfg.ranks {
            let origin = decomp.origin(r);
            let o = TensorBuf::new(
                vec![3],
                vec![origin[0] as f32, origin[1] as f32, origin[2] as f32],
            );
            let out = exec
                .call(
                    &mut comm,
                    &mut scale,
                    r,
                    &format!("assemble_rhs3d_n{n}"),
                    &[o, TensorBuf::scalar1(h)],
                )?
                .unwrap();
            rhs.push(out[0].data.clone());
            // mesh partitioning/bookkeeping
            exec.charge(&mut comm, &mut scale, r, bookkeeping);
        }
    }
    comm.allreduce(8); // dof-count agreement
    mark(&mut comm, &mut breakdown, "assemble");

    // -- refine -------------------------------------------------------------
    // one uniform refinement pass: per-cell work + ownership exchange
    exec.charge_uniform(
        &mut comm,
        &mut scale,
        Duration::from_nanos(REFINE_NS_PER_CELL * (n * n * n) as u64),
    );
    exchange_halos_modeled(&decomp, &mut comm, decomp.face_bytes());
    comm.allreduce(8);
    mark(&mut comm, &mut breakdown, "refine");

    // -- solve ---------------------------------------------------------------
    let cg_cfg = CgConfig {
        tol: cfg.tol,
        modeled_iters: estimate_cg_iters(decomp.n_global()[0], cfg.tol),
        ..CgConfig::default()
    };
    let outcome = distributed_cg(exec, &mut comm, &mut scale, &decomp, &rhs, &cg_cfg)?;
    mark(&mut comm, &mut breakdown, "solve");

    // -- io --------------------------------------------------------------------
    let mut fs = setup.data_fs();
    let chunk = (n * n * n * 4) as u64;
    let io_start = comm.max_clock();
    for r in 0..cfg.ranks {
        let node = comm.allocation().node_of[r];
        let done = fs.open_write(io_start, node, chunk);
        comm.advance(r, done.max(comm.clock(r)) - comm.clock(r));
    }
    mark(&mut comm, &mut breakdown, "io");

    // keep solver provenance in the breakdown consumer's reach
    let _ = outcome;
    Ok(breakdown)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::CalibrationTable;

    fn run(platform: Platform, ranks: usize, python: bool, seed: u64) -> PhaseBreakdown {
        let table = CalibrationTable::builtin_fallback();
        let cfg = if python {
            AppConfig::python(ranks, seed)
        } else {
            AppConfig::cpp(ranks, seed)
        };
        run_poisson_app(platform, &mut Exec::Modeled { table: &table }, &cfg).unwrap()
    }

    #[test]
    fn phases_present_and_ordered() {
        let b = run(Platform::Native, 24, false, 0);
        assert_eq!(
            b.phase_names(),
            &["assemble", "refine", "solve", "io"]
                .map(String::from)
        );
        let b = run(Platform::ShifterSystemMpi, 24, true, 0);
        assert_eq!(b.phase_names()[0], "import");
    }

    #[test]
    fn fig3_shape_native_matches_shifter_system_mpi() {
        for ranks in [24usize, 96] {
            let native = run(Platform::Native, ranks, false, 1).total();
            let shifter = run(Platform::ShifterSystemMpi, ranks, false, 1).total();
            let rel = (shifter - native).abs() / native;
            assert!(rel < 0.10, "ranks {ranks}: shifter differs {rel:.3}");
        }
    }

    #[test]
    fn fig3_shape_container_mpi_blows_up_across_nodes() {
        // single node (24 ranks): acceptable; multi-node: catastrophic
        let one_node = run(Platform::ShifterContainerMpi, 24, false, 2).get("solve")
            / run(Platform::Native, 24, false, 2).get("solve");
        let multi_node = run(Platform::ShifterContainerMpi, 96, false, 2).get("solve")
            / run(Platform::Native, 96, false, 2).get("solve");
        assert!(one_node < 2.0, "single-node ratio {one_node:.2}");
        assert!(multi_node > 5.0, "multi-node ratio {multi_node:.2}");
    }

    #[test]
    fn fig4_shape_import_dominates_native_python() {
        let native = run(Platform::Native, 96, true, 3);
        let shifter = run(Platform::ShifterSystemMpi, 96, true, 3);
        // compute phases comparable...
        let rel = (shifter.get("solve") - native.get("solve")).abs() / native.get("solve");
        assert!(rel < 0.15, "solve phases differ {rel:.3}");
        // ...but native total >> container total, due to import
        assert!(
            native.total() > 1.5 * shifter.total(),
            "native {} vs shifter {}",
            native.total(),
            shifter.total()
        );
        assert!(native.get("import") > 5.0 * shifter.get("import"));
    }

    #[test]
    fn import_cost_grows_with_ranks_natively() {
        let a = run(Platform::Native, 24, true, 4).get("import");
        let b = run(Platform::Native, 96, true, 4).get("import");
        assert!(b > 1.5 * a, "24 ranks {a}, 96 ranks {b}");
    }

    #[test]
    fn solve_dominates_cpp_run() {
        let b = run(Platform::Native, 48, false, 5);
        assert!(b.get("solve") > b.get("assemble"));
        assert!(b.get("solve") > b.get("io"));
    }

    #[test]
    fn batched_cpp_run_bit_identical_to_per_rank() {
        // no import phase: every phase of the batched engine must agree
        // with the per-rank reference to the nanosecond, jitter included
        let table = CalibrationTable::builtin_fallback();
        for platform in [Platform::Native, Platform::ShifterContainerMpi] {
            for ranks in [24usize, 96] {
                let go = |cfg: AppConfig| {
                    run_poisson_app(platform, &mut Exec::Modeled { table: &table }, &cfg)
                        .unwrap()
                };
                let b = go(AppConfig::cpp(ranks, 7));
                let p = go(AppConfig::cpp(ranks, 7).per_rank());
                assert_eq!(b.phase_names(), p.phase_names());
                for phase in b.phase_names() {
                    assert_eq!(
                        b.get(phase).to_bits(),
                        p.get(phase).to_bits(),
                        "{platform} ranks {ranks} phase {phase}"
                    );
                }
            }
        }
    }

    #[test]
    fn batched_python_run_matches_per_rank_outside_import() {
        let table = CalibrationTable::builtin_fallback();
        let go = |cfg: AppConfig| {
            run_poisson_app(
                Platform::Native,
                &mut Exec::Modeled { table: &table },
                &cfg,
            )
            .unwrap()
        };
        let b = go(AppConfig::python(96, 3));
        let p = go(AppConfig::python(96, 3).per_rank());
        // import collapses per-node (noise per burst instead of per
        // rank): agree within the noise band only
        let ratio = b.get("import") / p.get("import");
        assert!((0.4..2.5).contains(&ratio), "import batched/per-rank {ratio:.3}");
        // the phases after the import barrier are time-shift invariant
        // and must be identical to the bit
        for phase in ["assemble", "refine", "solve", "io"] {
            assert_eq!(b.get(phase).to_bits(), p.get(phase).to_bits(), "{phase}");
        }
    }

    #[test]
    fn paper_scale_cell_runs_fast_in_batched_mode() {
        // 1536 ranks — unreachable for the per-rank path in test time,
        // a blink for the class-batched engine
        let b = run(Platform::ShifterSystemMpi, 1536, false, 1);
        assert!(b.total() > 0.0);
        assert!(b.get("solve") > b.get("assemble"));
    }
}
