//! HPGMG-FE (Fig 5): geometric-multigrid throughput benchmark.
//!
//! HPGMG ranks machines by finite-element multigrid throughput (DOF/s,
//! higher is better).  Our port runs V-cycles on the exported ladder and
//! reports `dofs * cycles / wall`.  It is the one workload where the
//! *architecture* of the binary matters (§4.3): images built without
//! `ARCH_OPT` lose AVX on the tuned smoother loops and pay the ~3 %
//! penalty native builds (and `ARCH_OPT` images) do not.

use anyhow::Result;

use crate::cluster::MachineSpec;
use crate::des::VirtualTime;
use crate::fem::exec::Exec;
use crate::fem::gmg::{vcycles, GmgConfig, LADDER};
use crate::fem::grid::Decomp;
use crate::platform::Platform;
use crate::workload::RunSetup;

/// One HPGMG run.
#[derive(Debug, Clone)]
pub struct HpgmgConfig {
    /// Machine the run is placed on.
    pub machine: MachineSpec,
    /// MPI ranks.
    pub ranks: usize,
    /// Problem-size index: 0 = 32³ blocks (largest), 1 = 16³, 2 = 8³.
    pub fine_level: usize,
    /// V-cycles per run.
    pub cycles: usize,
    /// Simulation seed.
    pub seed: u64,
    /// Whether the image was built with `ARCH_OPT`.
    pub arch_optimized_image: bool,
    /// Rank-class batched engine for modeled runs (`false` forces the
    /// O(ranks) per-rank reference path; VirtualTime-identical).
    pub batched: bool,
}

impl HpgmgConfig {
    /// The Fig 5a setup (16-core workstation).
    pub fn workstation(fine_level: usize, seed: u64) -> Self {
        HpgmgConfig {
            machine: MachineSpec::workstation(),
            ranks: 16,
            fine_level,
            cycles: 8,
            seed,
            arch_optimized_image: false,
            batched: true,
        }
    }

    /// The Fig 5b setup (Edison, 192 cores).
    pub fn edison(fine_level: usize, seed: u64) -> Self {
        HpgmgConfig {
            machine: MachineSpec::edison(),
            ranks: 192,
            fine_level,
            cycles: 8,
            seed,
            arch_optimized_image: false,
            batched: true,
        }
    }
}

/// Result: the figure's y-axis.
#[derive(Debug, Clone)]
pub struct HpgmgResult {
    /// Degrees of freedom solved.
    pub dofs: u64,
    /// Virtual wall time of the solve.
    pub wall_seconds: f64,
    /// The figure's y-axis: DOF/s.
    pub dofs_per_second: f64,
}

/// Run HPGMG under `platform`.
pub fn run_hpgmg(platform: Platform, exec: &mut Exec, cfg: &HpgmgConfig) -> Result<HpgmgResult> {
    let mut setup = RunSetup::new(cfg.machine.clone(), platform, cfg.ranks, cfg.seed);
    if cfg.arch_optimized_image {
        let (image, _) = crate::workload::fenics_image_opt(true);
        setup.image = image;
    }
    let decomp = Decomp::new(cfg.ranks, LADDER[cfg.fine_level]);
    let mut comm = setup.comm();
    if cfg.batched && !exec.is_real() {
        // class-batch the modeled ladder (bit-identical; see
        // tests/batched_equivalence.rs and fem::gmg's equivalence test)
        comm.set_classes(decomp.rank_classes(comm.allocation()));
    }
    // tuned = true: HPGMG is the workload where arch flags matter
    let mut scale = setup.scale(true);

    let rhs: Vec<Vec<f32>> = if exec.is_real() {
        let block = LADDER[cfg.fine_level].pow(3);
        (0..cfg.ranks)
            .map(|r| {
                (0..block)
                    .map(|i| (((i + r) % 17) as f32 - 8.0) * 0.1)
                    .collect()
            })
            .collect()
    } else {
        Vec::new()
    };

    let gmg_cfg = GmgConfig {
        nu: 2,
        cycles: cfg.cycles,
        fine_level: cfg.fine_level,
    };
    let outcome = vcycles(exec, &mut comm, &mut scale, &decomp, &rhs, &gmg_cfg)?;

    let wall = (comm.max_clock() - VirtualTime::ZERO).as_secs_f64();
    let dofs = decomp.dofs();
    Ok(HpgmgResult {
        dofs,
        wall_seconds: wall,
        dofs_per_second: dofs as f64 * outcome.cycles as f64 / wall,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::CalibrationTable;

    fn run(platform: Platform, cfg: &HpgmgConfig) -> HpgmgResult {
        let table = CalibrationTable::builtin_fallback();
        run_hpgmg(platform, &mut Exec::Modeled { table: &table }, cfg).unwrap()
    }

    #[test]
    fn fig5a_native_beats_containers_by_a_few_percent() {
        let cfg = HpgmgConfig::workstation(0, 1);
        let native = run(Platform::Native, &cfg).dofs_per_second;
        let docker = run(Platform::Docker, &cfg).dofs_per_second;
        let rkt = run(Platform::Rkt, &cfg).dofs_per_second;
        for (name, t) in [("docker", docker), ("rkt", rkt)] {
            let gap = (native - t) / native;
            assert!(
                (0.005..0.08).contains(&gap),
                "{name}: native should win by ~3%, gap {gap:.4}"
            );
        }
    }

    #[test]
    fn arch_opt_image_closes_the_gap() {
        let mut cfg = HpgmgConfig::workstation(0, 2);
        let native = run(Platform::Native, &cfg).dofs_per_second;
        cfg.arch_optimized_image = true;
        let docker_opt = run(Platform::Docker, &cfg).dofs_per_second;
        let gap = (native - docker_opt).abs() / native;
        assert!(gap < 0.02, "ARCH_OPT should match native: gap {gap:.4}");
    }

    #[test]
    fn fig5b_shifter_matches_native_at_larger_sizes() {
        let cfg = HpgmgConfig::edison(0, 3);
        let native = run(Platform::Native, &cfg).dofs_per_second;
        let shifter = run(Platform::ShifterSystemMpi, &cfg).dofs_per_second;
        let gap = (native - shifter).abs() / native;
        assert!(gap < 0.08, "gap {gap:.4}");
    }

    #[test]
    fn throughput_grows_with_problem_size() {
        // larger local blocks amortise latency: higher DOF/s
        let big = run(Platform::Native, &HpgmgConfig::workstation(0, 4));
        let small = run(Platform::Native, &HpgmgConfig::workstation(2, 4));
        assert!(
            big.dofs_per_second > small.dofs_per_second,
            "big {} vs small {}",
            big.dofs_per_second,
            small.dofs_per_second
        );
        assert!(big.dofs > small.dofs);
    }

    #[test]
    fn dofs_accounting() {
        let cfg = HpgmgConfig::workstation(0, 5);
        let r = run(Platform::Native, &cfg);
        // 16 ranks x 32^3
        assert_eq!(r.dofs, 16 * 32 * 32 * 32);
        assert!(r.wall_seconds > 0.0);
    }
}
