//! Co-scheduled tenants on the shared parallel filesystem — the §4
//! discussion case the paper raises but never measures.
//!
//! Two jobs run side by side on Edison:
//!
//! * **the Python tenant** — `import fenics` on every rank, the Fig 4
//!   metadata storm.  Natively its lookups hammer the shared Lustre
//!   MDS; containerised (Shifter) they hit the node-local image mount
//!   and the shared MDS never sees them.
//! * **the C++ tenant** — a solver that computes for a fixed span and
//!   then checkpoints: one open + write per rank through the *same*
//!   Lustre.  Its checkpoint opens queue at the same
//!   [`FifoResource`](crate::des::FifoResource) MDS handlers the
//!   Python tenant is saturating.
//!
//! The measurement is the C++ tenant's checkpoint-write time: solo,
//! next to a native Python tenant, and next to a containerised one.
//! Containerising the *co-tenant* returns the writer to solo time —
//! bit-identical, because the image-mounted import never touches the
//! shared filesystem (the per-node squashfs fetch is charged to the
//! image's backing store, not the scratch OSTs — the one
//! simplification, noted where it is made).

use anyhow::Result;

use crate::cluster::{launch, Allocation, MachineSpec};
use crate::des::{Duration, VirtualTime};
use crate::fs::{FileSystem, ImageFs, ParallelFs};
use crate::platform::Platform;
use crate::pyimport::{module_burst, replay_batched, ModuleGraph};

/// Configuration of one co-scheduling experiment.
#[derive(Debug, Clone)]
pub struct MixedConfig {
    /// MPI ranks per tenant (both jobs are sized equally).
    pub ranks: usize,
    /// Simulation seed (drives the shared filesystem's noise streams).
    pub seed: u64,
    /// The co-scheduled Python tenant's platform; `None` runs the C++
    /// tenant alone (the interference baseline).
    pub python: Option<Platform>,
    /// C++ tenant compute span before its checkpoint write.
    pub compute: Duration,
    /// Checkpoint bytes per C++ rank.
    pub chunk_bytes: u64,
}

impl MixedConfig {
    /// The standard cell: 2 s of compute, ~1 MB checkpoint per rank.
    pub fn new(ranks: usize, seed: u64, python: Option<Platform>) -> Self {
        MixedConfig {
            ranks,
            seed,
            python,
            compute: Duration::from_secs_f64(2.0),
            chunk_bytes: 32 * 32 * 32 * 4 * 8,
        }
    }
}

/// Outcome of one co-scheduling run (all spans in virtual seconds).
#[derive(Debug, Clone)]
pub struct MixedReport {
    /// The C++ tenant's checkpoint-write span, co-scheduled.
    pub cpp_io: f64,
    /// The same write with no co-tenant (identical filesystem seed).
    pub cpp_io_solo: f64,
    /// The C++ tenant's total run (compute + checkpoint).
    pub cpp_total: f64,
    /// The Python tenant's import wall time (0 when absent).
    pub import_wall: f64,
    /// Metadata RPCs the shared MDS served.
    pub mds_served: u64,
}

impl MixedReport {
    /// Checkpoint slowdown relative to solo (1.0 = unperturbed).
    pub fn slowdown(&self) -> f64 {
        if self.cpp_io_solo > 0.0 {
            self.cpp_io / self.cpp_io_solo
        } else {
            1.0
        }
    }
}

/// The C++ tenant's checkpoint: one open + write per rank, all arriving
/// together at `at` (the bulk-synchronous solver finishes its compute
/// phase everywhere at once).  Returns the last rank's completion.
fn checkpoint(
    fs: &mut dyn FileSystem,
    alloc: &Allocation,
    at: VirtualTime,
    chunk: u64,
) -> VirtualTime {
    let mut done = at;
    for &node in &alloc.node_of {
        done = done.max(fs.open_write(at, node, chunk));
    }
    done
}

/// The Python tenant's node-batched import replay with the C++
/// tenant's checkpoint injected once every node's import frontier has
/// passed `t_io`.  The interleave is approximate at burst granularity:
/// [`FifoResource`](crate::des::FifoResource) is FIFO by *submission*
/// (arrival only lower-bounds the start), so bursts a faster node
/// already submitted with arrivals just past `t_io` stay queued ahead
/// of the checkpoint — an overstatement of interference bounded by the
/// inter-node clock skew, which is small because every node runs the
/// same module list with the same rank count.  (If the import drains
/// before `t_io`, the checkpoint meets an idle MDS; only the noise
/// stream, already advanced by the storm, still differs from solo.)
fn co_replay(
    graph: &ModuleGraph,
    alloc_py: &Allocation,
    alloc_cpp: &Allocation,
    fs: &mut ParallelFs,
    t_io: VirtualTime,
    chunk: u64,
) -> (VirtualTime, VirtualTime) {
    let nodes = alloc_py.nodes_used;
    let mut count = vec![0u32; nodes];
    for &n in &alloc_py.node_of {
        count[n] += 1;
    }
    let mut node_clock = vec![VirtualTime::ZERO; nodes];
    let mut io_done: Option<VirtualTime> = None;
    for module in &graph.modules {
        if io_done.is_none() {
            let frontier = node_clock.iter().copied().min().unwrap_or(VirtualTime::ZERO);
            if frontier >= t_io {
                io_done = Some(checkpoint(fs, alloc_cpp, t_io, chunk));
            }
        }
        for (node, clock) in node_clock.iter_mut().enumerate() {
            *clock = module_burst(fs, node, count[node], module, *clock);
        }
    }
    let io_done =
        io_done.unwrap_or_else(|| checkpoint(fs, alloc_cpp, t_io, chunk));
    let import_done = node_clock.iter().copied().max().unwrap_or(VirtualTime::ZERO);
    (import_done, io_done)
}

/// Run one co-scheduling cell.  Deterministic for a fixed config: the
/// shared and solo filesystems are seeded identically, so with a
/// containerised (or absent) Python tenant the co-scheduled checkpoint
/// is *bit-identical* to solo.
pub fn run_mixed_fleet(cfg: &MixedConfig) -> Result<MixedReport> {
    let machine = MachineSpec::edison();
    let alloc_cpp = launch(&machine, cfg.ranks)?;
    let t_io = VirtualTime::ZERO + cfg.compute;

    // solo baseline: the identical checkpoint against an identically
    // seeded, otherwise idle Lustre
    let mut solo_fs = ParallelFs::edison(cfg.seed);
    let solo_done = checkpoint(&mut solo_fs, &alloc_cpp, t_io, cfg.chunk_bytes);
    let cpp_io_solo = (solo_done - t_io).as_secs_f64();

    let mut shared = ParallelFs::edison(cfg.seed);
    let (import_wall, cpp_done) = match cfg.python {
        None => (
            Duration::ZERO,
            checkpoint(&mut shared, &alloc_cpp, t_io, cfg.chunk_bytes),
        ),
        Some(platform) => {
            let alloc_py = launch(&machine, cfg.ranks)?;
            let graph = ModuleGraph::fenics_stack();
            if platform.containerised() {
                // image-mounted import: the metadata storm stays on the
                // node-local mount; its backing store (the image blob
                // fetch) is modelled separately from the scratch Lustre
                let mut image_fs =
                    ImageFs::new(1_200_000_000, ParallelFs::edison(cfg.seed.wrapping_add(1)));
                let report =
                    replay_batched(&graph, &alloc_py, &mut image_fs, VirtualTime::ZERO);
                let done = checkpoint(&mut shared, &alloc_cpp, t_io, cfg.chunk_bytes);
                (report.wall, done)
            } else {
                // native import: both tenants meet at the shared MDS
                let (import_done, io_done) = co_replay(
                    &graph,
                    &alloc_py,
                    &alloc_cpp,
                    &mut shared,
                    t_io,
                    cfg.chunk_bytes,
                );
                (import_done - VirtualTime::ZERO, io_done)
            }
        }
    };

    Ok(MixedReport {
        cpp_io: (cpp_done - t_io).as_secs_f64(),
        cpp_io_solo,
        cpp_total: cpp_done.as_secs_f64(),
        import_wall: import_wall.as_secs_f64(),
        mds_served: shared.mds_served(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_co_tenant_slows_the_checkpoint() {
        let r = run_mixed_fleet(&MixedConfig::new(96, 1, Some(Platform::Native))).unwrap();
        assert!(r.cpp_io > 0.0 && r.cpp_io_solo > 0.0);
        assert!(
            r.slowdown() > 1.5,
            "native import storm should delay the co-tenant: {:.3}x",
            r.slowdown()
        );
        assert!(r.import_wall > 0.0);
    }

    #[test]
    fn containerised_co_tenant_is_bit_identical_to_solo() {
        let co = run_mixed_fleet(&MixedConfig::new(48, 2, Some(Platform::ShifterSystemMpi)))
            .unwrap();
        assert_eq!(
            co.cpp_io.to_bits(),
            co.cpp_io_solo.to_bits(),
            "image-mounted import must leave the shared Lustre untouched"
        );
        assert!(co.import_wall > 0.0);
        let solo = run_mixed_fleet(&MixedConfig::new(48, 2, None)).unwrap();
        assert_eq!(solo.cpp_io.to_bits(), solo.cpp_io_solo.to_bits());
        assert_eq!(co.cpp_io.to_bits(), solo.cpp_io.to_bits());
    }

    #[test]
    fn interference_grows_with_co_tenant_ranks() {
        let small = run_mixed_fleet(&MixedConfig::new(24, 3, Some(Platform::Native))).unwrap();
        let large = run_mixed_fleet(&MixedConfig::new(96, 3, Some(Platform::Native))).unwrap();
        assert!(
            large.cpp_io > small.cpp_io,
            "more importing ranks, deeper MDS backlog: {} vs {}",
            small.cpp_io,
            large.cpp_io
        );
    }

    #[test]
    fn deterministic_per_seed() {
        let cfg = MixedConfig::new(48, 7, Some(Platform::Native));
        let a = run_mixed_fleet(&cfg).unwrap();
        let b = run_mixed_fleet(&cfg).unwrap();
        assert_eq!(a.cpp_io.to_bits(), b.cpp_io.to_bits());
        assert_eq!(a.import_wall.to_bits(), b.import_wall.to_bits());
        assert_eq!(a.mds_served, b.mds_served);
    }

    #[test]
    fn mds_accounting_reflects_the_storm() {
        let solo = run_mixed_fleet(&MixedConfig::new(24, 4, None)).unwrap();
        let co = run_mixed_fleet(&MixedConfig::new(24, 4, Some(Platform::Native))).unwrap();
        assert!(co.mds_served > 10 * solo.mds_served.max(1));
    }
}
