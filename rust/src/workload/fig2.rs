//! Fig 2: four single-process FEniCS tests on the workstation.
//!
//! 'Poisson LU' solves a 2D Poisson problem by dense LU; 'Poisson AMG'
//! solves 3D Poisson with CG preconditioned by multigrid (AMG → GMG
//! substitution); 'IO' reads a large mesh and writes a solution through
//! the platform's filesystem; 'elasticity' solves the 3D Lamé system
//! with CG.  Reported run times exclude container start-up and JIT, as
//! in the paper (§4.1).

use anyhow::Result;

use crate::des::{Duration, VirtualTime};
use crate::fem::cg::{distributed_cg, precond_cg_single, CgConfig};
use crate::fem::exec::Exec;
use crate::fem::grid::Decomp;
use crate::fem::lu::lu_solve;
use crate::fs::FsOp;
use crate::platform::Platform;
use crate::workload::RunSetup;

use crate::cluster::MachineSpec;

/// The four workstation tests, in the paper's order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Fig2Test {
    /// Poisson problem, direct LU solver.
    PoissonLu,
    /// Poisson problem, algebraic-multigrid-preconditioned CG.
    PoissonAmg,
    /// Mesh + function I/O to disk.
    Io,
    /// 3D linear-elasticity assembly + solve.
    Elasticity,
}

impl Fig2Test {
    /// The four workstation tests, in figure order.
    pub const ALL: [Fig2Test; 4] = [
        Fig2Test::PoissonLu,
        Fig2Test::PoissonAmg,
        Fig2Test::Io,
        Fig2Test::Elasticity,
    ];

    /// Row label used in Fig 2.
    pub fn label(self) -> &'static str {
        match self {
            Fig2Test::PoissonLu => "Poisson LU",
            Fig2Test::PoissonAmg => "Poisson AMG",
            Fig2Test::Io => "IO",
            Fig2Test::Elasticity => "elasticity",
        }
    }
}

/// Mesh/solution sizes for the IO test (bytes). Sized so the test takes
/// seconds on a workstation disk, like the paper's.
const IO_MESH_BYTES: u64 = 800_000_000;
const IO_SOLUTION_BYTES: u64 = 200_000_000;

/// Iterations the modeled solvers charge (solver-phase structure; the
/// real-mode integration tests pin these against actual solves).
const AMG_MODELED_ITERS: usize = 14;
const ELASTICITY_MODELED_ITERS: usize = 80;
/// Repeated solves per test so run times land in the paper's "seconds"
/// regime rather than microseconds (the paper's tests use meshes far
/// larger than our exported 32³ blocks; repetition recovers the same
/// compute-bound behaviour at identical per-call cost).
const SOLVE_ROUNDS: usize = 6;

/// Run one Fig 2 test on `platform`; returns the test's run time.
pub fn run_fig2(
    test: Fig2Test,
    platform: Platform,
    exec: &mut Exec,
    seed: u64,
) -> Result<Duration> {
    let setup = RunSetup::new(MachineSpec::workstation(), platform, 1, seed);
    let mut comm = setup.comm();
    let mut scale = setup.scale(false);

    match test {
        Fig2Test::PoissonLu => {
            for _ in 0..SOLVE_ROUNDS {
                let rhs = vec![1.0f32; 32 * 32];
                lu_solve(exec, &mut comm, &mut scale, &rhs)?;
            }
        }
        Fig2Test::PoissonAmg => {
            for round in 0..SOLVE_ROUNDS {
                let rhs: Vec<f32> = (0..32usize.pow(3))
                    .map(|i| ((i + round) % 11) as f32 * 0.1 - 0.5)
                    .collect();
                precond_cg_single(
                    exec,
                    &mut comm,
                    &mut scale,
                    &rhs,
                    1e-5,
                    200,
                    AMG_MODELED_ITERS,
                )?;
            }
        }
        Fig2Test::Io => {
            // mesh read + solution write through the platform's data FS
            let mut fs = setup.data_fs();
            let t0 = comm.clock(0);
            let t1 = fs.submit(t0, 0, FsOp::Open);
            let t2 = fs.submit(t1, 0, FsOp::Read { bytes: IO_MESH_BYTES });
            // partition/convert the mesh (compute, scaled by platform)
            comm.advance(0, Duration::from_secs_f64(0.8).scale(scale.factor));
            let t3 = fs.submit(t2.max(comm.clock(0)), 0, FsOp::Open);
            let t4 = fs.submit(t3, 0, FsOp::Write { bytes: IO_SOLUTION_BYTES });
            comm.advance_all_to(t4);
        }
        Fig2Test::Elasticity => {
            let n = 16usize;
            let decomp = Decomp::new(1, n);
            let rhs: Vec<Vec<f32>> = vec![(0..3 * n * n * n)
                .map(|i| ((i % 7) as f32 - 3.0) * 0.05)
                .collect()];
            let cfg = CgConfig {
                elasticity: true,
                tol: 1e-5,
                modeled_iters: ELASTICITY_MODELED_ITERS,
                ..CgConfig::default()
            };
            for _ in 0..SOLVE_ROUNDS {
                distributed_cg(
                    exec,
                    &mut comm,
                    &mut scale,
                    &decomp,
                    if exec.is_real() { &rhs } else { &[] },
                    &cfg,
                )?;
            }
        }
    }
    Ok(comm.max_clock() - VirtualTime::ZERO)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::CalibrationTable;

    fn run_modeled(test: Fig2Test, platform: Platform, seed: u64) -> f64 {
        let table = CalibrationTable::builtin_fallback();
        run_fig2(test, platform, &mut Exec::Modeled { table: &table }, seed)
            .unwrap()
            .as_secs_f64()
    }

    #[test]
    fn all_tests_produce_positive_times() {
        for test in Fig2Test::ALL {
            for platform in Platform::workstation_set() {
                let t = run_modeled(test, platform, 0);
                assert!(t > 0.0, "{test:?} on {platform}");
            }
        }
    }

    #[test]
    fn docker_rkt_native_within_percent_scale() {
        // the paper's headline: container ≈ native on compute tests
        for test in [Fig2Test::PoissonLu, Fig2Test::PoissonAmg, Fig2Test::Elasticity] {
            let native = run_modeled(test, Platform::Native, 1);
            let docker = run_modeled(test, Platform::Docker, 1);
            let rkt = run_modeled(test, Platform::Rkt, 1);
            for (name, t) in [("docker", docker), ("rkt", rkt)] {
                let diff = (t - native).abs() / native;
                assert!(diff < 0.05, "{test:?} {name}: {diff:.3} vs native");
            }
        }
    }

    #[test]
    fn vm_pays_roughly_fifteen_percent_on_compute() {
        for test in [Fig2Test::PoissonAmg, Fig2Test::Elasticity] {
            let native = run_modeled(test, Platform::Native, 2);
            let vm = run_modeled(test, Platform::Vm, 2);
            let ratio = vm / native;
            assert!(
                (1.08..1.25).contains(&ratio),
                "{test:?}: vm/native = {ratio:.3}"
            );
        }
    }

    #[test]
    fn vm_io_slower_than_native_io() {
        let native = run_modeled(Fig2Test::Io, Platform::Native, 3);
        let vm = run_modeled(Fig2Test::Io, Platform::Vm, 3);
        assert!(vm > 1.1 * native, "vm {vm} vs native {native}");
    }

    #[test]
    fn io_test_is_io_bound() {
        // IO time must dwarf its compute fraction
        let t = run_modeled(Fig2Test::Io, Platform::Native, 4);
        assert!(t > 1.5, "expected seconds of IO, got {t}");
    }

    #[test]
    fn repeated_runs_jitter_but_agree() {
        let a = run_modeled(Fig2Test::PoissonAmg, Platform::Native, 10);
        let b = run_modeled(Fig2Test::PoissonAmg, Platform::Native, 11);
        assert!(a != b, "different seeds should jitter");
        assert!((a - b).abs() / a < 0.05);
    }
}
