//! The paper's benchmark programs.
//!
//! * [`fig2`] — the four single-process workstation tests (Poisson LU,
//!   Poisson AMG, IO, Elasticity) across native/docker/rkt/VM.
//! * [`poisson_app`] — the Edison test program of Figs 3 and 4
//!   (assemble → refine → solve → IO, plus the Python import phase),
//!   distributed over 24–192 ranks.
//! * [`hpgmg`] — the HPGMG-FE throughput benchmark of Fig 5.
//! * [`mixed`] — co-scheduled C++/Python tenants contending for the
//!   shared Lustre (the `mixed-fleet` scenario).
//! * [`ablate`] — sensitivity sweeps over the modelling choices behind
//!   each figure (MDS pool, fallback NIC, smoothing depth, layering).
//!
//! All workloads run through [`RunSetup`], which wires the platform's
//! container runtime, MPI resolution, filesystem policy, and overheads —
//! the same plumbing an experiment on the real systems would traverse.

pub mod ablate;
pub mod fig2;
pub mod hpgmg;
pub mod mixed;
pub mod poisson_app;

pub use ablate::{Ablation, AblationRow};
pub use fig2::{run_fig2, Fig2Test};
pub use hpgmg::{run_hpgmg, HpgmgConfig, HpgmgResult};
pub use mixed::{run_mixed_fleet, MixedConfig, MixedReport};
pub use poisson_app::{run_poisson_app, AppConfig};

use crate::cluster::{launch, MachineSpec};
use crate::container::runtime::{by_kind, ContainerRuntime, FsPolicy};
use crate::container::{Builder, Buildfile, Image, LayerStore};
use crate::des::Duration;
use crate::fem::exec::ComputeScale;
use crate::fs::{FileSystem, ImageFs, LocalFs, ParallelFs};
use crate::mpi::{AbiResolver, Comm};
use crate::net::Fabric;
use crate::platform::Platform;

/// The standard FEniCS image every containerised experiment runs
/// (mirrors `quay.io/fenicsproject/stable:2016.1.0r1`).
pub fn fenics_image() -> (Image, LayerStore) {
    fenics_image_opt(false)
}

/// As [`fenics_image`], optionally with host-architecture optimisation
/// (the `ARCH_OPT` buildfile directive — removes the Fig 5a penalty).
pub fn fenics_image_opt(arch_opt: bool) -> (Image, LayerStore) {
    let text = format!(
        "FROM quay.io/fenicsproject/stable:2016.1.0r1\n\
         USER fenics\n\
         WORKDIR /home/fenics\n\
         ENV FENICS_VERSION=2016.1.0\n\
         {}ENTRYPOINT /bin/bash",
        if arch_opt { "ARCH_OPT\n" } else { "" }
    );
    let bf = Buildfile::parse(&text).expect("static buildfile parses");
    let mut store = LayerStore::new();
    let report = Builder::new()
        .build(&bf, "quay.io/fenicsproject/stable:2016.1.0r1", &mut store)
        .expect("known base");
    (report.image, store)
}

/// Everything needed to execute one (machine, platform, ranks) cell of
/// the experiment matrix.
pub struct RunSetup {
    /// Machine the cell runs on.
    pub machine: MachineSpec,
    /// Execution platform (native / container runtime).
    pub platform: Platform,
    /// MPI ranks.
    pub ranks: usize,
    /// Simulation seed.
    pub seed: u64,
    /// Image the platform deploys.
    pub image: Image,
}

impl RunSetup {
    /// A setup cell over the standard FEniCS image.
    pub fn new(machine: MachineSpec, platform: Platform, ranks: usize, seed: u64) -> Self {
        let (image, _) = fenics_image();
        RunSetup {
            machine,
            platform,
            ranks,
            seed,
            image,
        }
    }

    fn runtime(&self) -> Box<dyn ContainerRuntime> {
        by_kind(self.platform.runtime_kind())
    }

    /// Build the communicator with the fabric the ABI resolution yields.
    pub fn comm(&self) -> Comm {
        let resolution = AbiResolver {
            machine: &self.machine,
            runtime: self.platform.runtime_kind(),
            inject_host_mpi: self.platform.inject_host_mpi(),
        }
        .resolve();
        let alloc = launch(&self.machine, self.ranks).expect("allocation fits machine");
        Comm::new(alloc, Fabric::by_kind(resolution.fabric))
    }

    /// Compute scaling for this platform (VM factor, arch penalty when
    /// `tuned`, machine jitter).
    pub fn scale(&self, tuned: bool) -> ComputeScale {
        let rt = self.runtime();
        let arch = if tuned { rt.arch_penalty(&self.image) } else { 1.0 };
        ComputeScale::new(
            rt.compute_factor(),
            arch,
            self.seed,
            self.machine.compute_jitter,
        )
    }

    /// Container start overhead (zero for native).
    pub fn startup(&self) -> Duration {
        self.runtime().startup_overhead(&self.image)
    }

    /// The filesystem the application's *code/imports* come from.
    pub fn code_fs(&self) -> Box<dyn FileSystem> {
        match self.runtime().fs_policy() {
            FsPolicy::Host => {
                if self.machine.parallel_fs {
                    Box::new(ParallelFs::edison(self.seed))
                } else {
                    Box::new(LocalFs::default())
                }
            }
            FsPolicy::Overlay => {
                // union FS over the local layer store: metadata slightly
                // dearer than bare local, data near-native
                Box::new(LocalFs::new(Duration::from_micros(3), 480.0e6))
            }
            FsPolicy::ImageMount => Box::new(ImageFs::new(
                1_200_000_000,
                ParallelFs::edison(self.seed.wrapping_add(1)),
            )),
            FsPolicy::VmDisk => {
                // virtio block device: every op pays the hypervisor exit
                // (~15% data-path penalty, Fig 2 / Macdonnell & Lu [19])
                Box::new(LocalFs::new(Duration::from_micros(8), 435.0e6))
            }
        }
    }

    /// The filesystem application *data* IO goes to (the paper's best
    /// practice: bind-mounted host storage for data [12], so container
    /// platforms see near-host data rates; the VM still pays
    /// virtualisation).
    pub fn data_fs(&self) -> Box<dyn FileSystem> {
        if self.machine.parallel_fs {
            // scratch Lustre, containerised or not (Shifter images are
            // read-only: data always lands on the host FS)
            return Box::new(ParallelFs::edison(self.seed.wrapping_add(2)));
        }
        match self.runtime().fs_policy() {
            FsPolicy::VmDisk => Box::new(LocalFs::new(Duration::from_micros(8), 435.0e6)),
            FsPolicy::Overlay => Box::new(LocalFs::new(Duration::from_micros(2), 490.0e6)),
            _ => Box::new(LocalFs::default()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::FabricKind;

    #[test]
    fn fenics_image_is_realistic() {
        let (image, store) = fenics_image();
        assert!(image.size_bytes(&store) > 500_000_000);
        assert!(image.file_count(&store) > 4_000);
        assert!(!image.arch_optimized);
        let (opt, _) = fenics_image_opt(true);
        assert!(opt.arch_optimized);
        assert_ne!(image.id, opt.id);
    }

    #[test]
    fn setup_resolves_fabrics_per_platform() {
        let edison = MachineSpec::edison();
        let f = |p: Platform| {
            RunSetup::new(edison.clone(), p, 48, 0)
                .comm()
                .fabric()
                .kind
        };
        assert_eq!(f(Platform::Native), FabricKind::Aries);
        assert_eq!(f(Platform::ShifterSystemMpi), FabricKind::Aries);
        assert_eq!(f(Platform::ShifterContainerMpi), FabricKind::TcpEthernet);
    }

    #[test]
    fn vm_scale_is_slower() {
        let ws = MachineSpec::workstation();
        let mut vm = RunSetup::new(ws.clone(), Platform::Vm, 1, 0).scale(false);
        let mut native = RunSetup::new(ws, Platform::Native, 1, 0).scale(false);
        let d = Duration::from_millis(100);
        // strip jitter by comparing means over many applications
        let mean = |s: &mut ComputeScale| {
            (0..200)
                .map(|_| {
                    let mut c = Duration::ZERO;
                    c += d;
                    // apply through a scale-only path: use scale(factor)
                    s.factor * s.arch_factor
                })
                .sum::<f64>()
                / 200.0
        };
        assert!(mean(&mut vm) > 1.1);
        assert!((mean(&mut native) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn startup_zero_only_for_native() {
        let ws = MachineSpec::workstation();
        assert_eq!(
            RunSetup::new(ws.clone(), Platform::Native, 1, 0).startup(),
            Duration::ZERO
        );
        assert!(RunSetup::new(ws, Platform::Docker, 1, 0).startup() > Duration::ZERO);
    }

    #[test]
    fn code_fs_policies_differ() {
        use crate::des::VirtualTime;
        use crate::fs::FsOp;
        let edison = MachineSpec::edison();
        // Shifter's image mount: opens after warm-up are microseconds;
        // native Lustre opens cost MDS time
        let mut shifter_fs =
            RunSetup::new(edison.clone(), Platform::ShifterSystemMpi, 24, 1).code_fs();
        let mut native_fs = RunSetup::new(edison, Platform::Native, 24, 1).code_fs();
        let warm = shifter_fs.submit(VirtualTime::ZERO, 0, FsOp::Open);
        let second = shifter_fs.submit(warm, 0, FsOp::Open) - warm;
        let native_open =
            native_fs.submit(VirtualTime::ZERO, 0, FsOp::Open) - VirtualTime::ZERO;
        assert!(second < native_open);
    }
}
