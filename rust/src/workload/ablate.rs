//! Ablation studies over the simulator's modelling choices.
//!
//! DESIGN.md grounds each figure in a mechanism (MDS queueing for Fig 4,
//! NIC serialisation + latency for Fig 3, AVX arch flags for Fig 5a).
//! These sweeps vary each mechanism's parameter and report how the
//! corresponding figure statistic responds — showing the conclusions are
//! driven by the mechanism, not by a hand-picked constant.  Run with
//! `harbor ablate <study>`; asserted qualitatively in the unit tests.

use crate::cluster::{launch, MachineSpec};
use crate::des::{Duration, VirtualTime};
use crate::fem::exec::{ComputeScale, Exec};
use crate::fem::gmg::{vcycles, GmgConfig};
use crate::fem::grid::Decomp;
use crate::fs::{ImageFs, ParallelFs};
use crate::mpi::Comm;
use crate::net::Fabric;
use crate::pyimport::{replay, ModuleGraph};
use crate::runtime::CalibrationTable;

/// One ablation row: parameter value -> observed statistic(s).
#[derive(Debug, Clone)]
pub struct AblationRow {
    /// The swept parameter value.
    pub param: f64,
    /// Named statistics observed at this value.
    pub values: Vec<(String, f64)>,
}

/// A completed study.
#[derive(Debug, Clone)]
pub struct Ablation {
    /// Study name (CLI argument).
    pub name: String,
    /// Name of the swept parameter.
    pub param_name: String,
    /// One row per parameter value.
    pub rows: Vec<AblationRow>,
    /// What the sweep shows (printed under the table).
    pub conclusion: String,
}

impl Ablation {
    /// ASCII table rendering.
    pub fn render(&self) -> String {
        let mut s = format!("== ablation: {} ==\n", self.name);
        if let Some(first) = self.rows.first() {
            s.push_str(&format!("{:>14}", self.param_name));
            for (k, _) in &first.values {
                s.push_str(&format!("  {k:>16}"));
            }
            s.push('\n');
        }
        for row in &self.rows {
            s.push_str(&format!("{:>14.3}", row.param));
            for (_, v) in &row.values {
                s.push_str(&format!("  {v:>16.4}"));
            }
            s.push('\n');
        }
        s.push_str(&format!("-> {}\n", self.conclusion));
        s
    }
}

/// Fig 4 mechanism: the native import time vs the MDS handler pool.
/// More handlers = less serialisation; the container side is flat.
pub fn mds_handlers(ranks: usize) -> Ablation {
    let machine = MachineSpec::edison();
    let alloc = launch(&machine, ranks).expect("fits");
    let graph = ModuleGraph::fenics_stack();
    let mut rows = Vec::new();
    for handlers in [4usize, 8, 16, 32, 64, 128] {
        let mut native_fs = ParallelFs::new(
            handlers,
            Duration::from_micros(100),
            48.0e9,
            0.0, // noise off: isolate the queueing effect
            1,
        );
        let native = replay(&graph, &alloc, &mut native_fs, VirtualTime::ZERO)
            .wall
            .as_secs_f64();
        let mut image_fs = ImageFs::new(
            1_200_000_000,
            ParallelFs::new(handlers, Duration::from_micros(100), 48.0e9, 0.0, 2),
        );
        let shifter = replay(&graph, &alloc, &mut image_fs, VirtualTime::ZERO)
            .wall
            .as_secs_f64();
        rows.push(AblationRow {
            param: handlers as f64,
            values: vec![
                ("native [s]".into(), native),
                ("shifter [s]".into(), shifter),
                ("speedup".into(), native / shifter),
            ],
        });
    }
    Ablation {
        name: format!("Fig 4 vs MDS handler pool ({ranks} ranks)"),
        param_name: "mds handlers".into(),
        rows,
        conclusion: "native import scales ~1/handlers (pure queueing); the container \
                     path is handler-independent — the Fig 4 gap is the MDS, not a constant"
            .into(),
    }
}

/// Fig 3 mechanism: container-MPI blow-up vs the fallback NIC bandwidth.
pub fn nic_bandwidth(ranks: usize) -> Ablation {
    let table = CalibrationTable::builtin_fallback();
    let machine = MachineSpec::edison();
    let decomp = Decomp::new(ranks, 32);
    let mut rows = Vec::new();
    for mbps in [50.0f64, 117.0, 500.0, 1250.0, 5000.0, 10000.0] {
        let mut fabric = Fabric::tcp_ethernet();
        fabric.inter_node.beta_bytes_per_sec = mbps * 1e6;
        fabric.nic_bytes_per_sec = mbps * 1e6;
        let mut comm = Comm::new(launch(&machine, ranks).unwrap(), fabric);
        let mut aries = Comm::new(launch(&machine, ranks).unwrap(), Fabric::aries());
        let cfg = crate::fem::cg::CgConfig {
            modeled_iters: 50,
            ..Default::default()
        };
        for (c, _) in [(&mut comm, 0), (&mut aries, 1)] {
            crate::fem::cg::distributed_cg(
                &mut Exec::Modeled { table: &table },
                c,
                &mut ComputeScale::none(),
                &decomp,
                &[],
                &cfg,
            )
            .expect("modeled cg");
        }
        rows.push(AblationRow {
            param: mbps,
            values: vec![
                ("tcp solve [s]".into(), comm.max_clock().as_secs_f64()),
                ("aries [s]".into(), aries.max_clock().as_secs_f64()),
                (
                    "ratio".into(),
                    comm.max_clock().as_secs_f64() / aries.max_clock().as_secs_f64(),
                ),
            ],
        });
    }
    Ablation {
        name: format!("Fig 3 vs fallback-fabric bandwidth ({ranks} ranks)"),
        param_name: "NIC [MB/s]".into(),
        rows,
        conclusion: "the container-MPI penalty shrinks as the fallback fabric approaches \
                     Aries bandwidth but never reaches parity (50 us latency floor) — \
                     matching the paper's 'load the system MPI' recommendation"
            .into(),
    }
}

/// GMG design choice: smoothing sweeps per level (nu) vs virtual solve
/// time — V(1,1) is cheapest per cycle but converges slower; the modeled
/// cost says what the paper-style benchmark pays for robustness.
pub fn gmg_nu(ranks: usize) -> Ablation {
    let table = CalibrationTable::builtin_fallback();
    let machine = MachineSpec::edison();
    let decomp = Decomp::new(ranks, 32);
    let mut rows = Vec::new();
    for nu in [1usize, 2, 3, 4] {
        let mut comm = Comm::new(launch(&machine, ranks).unwrap(), Fabric::aries());
        vcycles(
            &mut Exec::Modeled { table: &table },
            &mut comm,
            &mut ComputeScale::none(),
            &decomp,
            &[],
            &GmgConfig {
                nu,
                cycles: 8,
                fine_level: 0,
            },
        )
        .expect("modeled gmg");
        let wall = comm.max_clock().as_secs_f64();
        rows.push(AblationRow {
            param: nu as f64,
            values: vec![
                ("8 cycles [s]".into(), wall),
                (
                    "Mdof/s".into(),
                    decomp.dofs() as f64 * 8.0 / wall / 1e6,
                ),
            ],
        });
    }
    Ablation {
        name: format!("HPGMG cost vs smoothing sweeps ({ranks} ranks)"),
        param_name: "nu".into(),
        rows,
        conclusion: "per-cycle cost is ~linear in nu; V(2,2) (the paper-era default) \
                     doubles the smoother work of V(1,1) for ~one extra digit per cycle"
            .into(),
    }
}

/// Image design choice: layer granularity vs incremental pull cost.
/// One fat layer re-ships everything on any change; many thin layers
/// pull incrementally but pay per-layer round-trips.
pub fn layer_granularity() -> Ablation {
    use crate::container::image::FileEntry;
    use crate::container::{Layer, LayerStore, Registry};

    let total_bytes: u64 = 1_000_000_000;
    let mut rows = Vec::new();
    for layers in [1usize, 2, 5, 10, 25, 50] {
        // build an image of `layers` equal layers, then "change" the last
        // one and measure the update pull
        let mut store = LayerStore::new();
        let make = |tag: &str, store: &mut LayerStore| {
            let mut ids = Vec::new();
            let mut parent = None;
            for i in 0..layers {
                let directive = if i == layers - 1 {
                    format!("RUN {tag}")
                } else {
                    format!("RUN step{i}")
                };
                let layer = Layer::derive(
                    parent.as_ref(),
                    &directive,
                    vec![FileEntry {
                        path: format!("/l{i}"),
                        bytes: total_bytes / layers as u64,
                    }],
                );
                parent = Some(layer.id.clone());
                ids.push(layer.id.clone());
                store.insert(layer);
            }
            crate::container::Image::seal(tag, ids, vec![], None, vec![], false)
        };
        let v1 = make("v1", &mut store);
        let v2 = make("v2", &mut store);
        let mut registry = Registry::new();
        registry.push(&v1, &store).unwrap();
        registry.push(&v2, &store).unwrap();
        let mut user = LayerStore::new();
        let (_, first) = registry.pull("v1", &mut user).unwrap();
        let (_, update) = registry.pull("v2", &mut user).unwrap();
        rows.push(AblationRow {
            param: layers as f64,
            values: vec![
                ("first pull [s]".into(), first.time.as_secs_f64()),
                ("update [s]".into(), update.time.as_secs_f64()),
                (
                    "update MB".into(),
                    update.bytes_transferred as f64 / 1e6,
                ),
            ],
        });
    }
    Ablation {
        name: "incremental pull vs layer granularity (1 GB image)".into(),
        param_name: "layers".into(),
        rows,
        conclusion: "a single fat layer re-ships the full GB on any change; past ~10 \
                     layers the per-layer RTT dominates first pulls — the FEniCS \
                     image's handful of role-separated layers (§3.4) is the sweet spot"
            .into(),
    }
}

/// All studies by name.
pub fn by_name(name: &str) -> Option<Ablation> {
    match name {
        "mds" => Some(mds_handlers(96)),
        "nic" => Some(nic_bandwidth(96)),
        "nu" => Some(gmg_nu(64)),
        "layers" => Some(layer_granularity()),
        _ => None,
    }
}

/// Every ablation study name, in CLI order.
pub const STUDIES: [&str; 4] = ["mds", "nic", "nu", "layers"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mds_ablation_shows_queueing() {
        let a = mds_handlers(48);
        // native time falls as handlers grow
        let first = a.rows.first().unwrap();
        let last = a.rows.last().unwrap();
        assert!(first.values[0].1 > 2.0 * last.values[0].1);
        // shifter roughly flat
        let shifter_span = a
            .rows
            .iter()
            .map(|r| r.values[1].1)
            .fold((f64::INFINITY, 0.0f64), |(lo, hi), v| (lo.min(v), hi.max(v)));
        assert!(shifter_span.1 / shifter_span.0 < 1.5);
    }

    #[test]
    fn nic_ablation_monotone_and_bounded_below() {
        let a = nic_bandwidth(48);
        let ratios: Vec<f64> = a.rows.iter().map(|r| r.values[2].1).collect();
        for w in ratios.windows(2) {
            assert!(w[1] <= w[0] * 1.01, "ratio should fall with bandwidth: {ratios:?}");
        }
        // latency floor: even at 10 GB/s TCP never reaches parity
        assert!(*ratios.last().unwrap() > 1.05);
    }

    #[test]
    fn nu_ablation_linearish() {
        let a = gmg_nu(8);
        let t1 = a.rows[0].values[0].1;
        let t4 = a.rows[3].values[0].1;
        assert!(t4 > 2.0 * t1 && t4 < 5.0 * t1, "t1={t1} t4={t4}");
    }

    #[test]
    fn layer_ablation_tradeoff() {
        let a = layer_granularity();
        let one = &a.rows[0];
        let many = a.rows.last().unwrap();
        // fat layer: update re-ships ~everything
        assert!(one.values[2].1 > 900.0);
        // thin layers: update ships ~1/50
        assert!(many.values[2].1 < 50.0);
        // but thin layers pay more round-trips on first pull
        assert!(many.values[0].1 > one.values[0].1);
    }

    #[test]
    fn registry_of_studies() {
        for s in STUDIES {
            assert!(by_name(s).is_some(), "{s}");
        }
        assert!(by_name("bogus").is_none());
        // and they all render
        let text = by_name("layers").unwrap().render();
        assert!(text.contains("ablation"));
        assert!(text.contains("->"));
    }
}
