//! Benchmark harness and paper-style reporting.
//!
//! Offline stand-in for `criterion`: [`repeat`] runs a measurement
//! closure `n` times (the paper uses 3–5 repetitions with error bars)
//! and aggregates into [`Stats`]; [`Figure`] renders grouped bars —
//! optionally stacked by phase — as ASCII (the terminal version of the
//! paper's Figs 2–5) and as JSON for machine consumption.

use std::collections::BTreeMap;

use crate::metrics::Stats;
use crate::util::json::Value;

/// Run `f` for `reps` repetitions (passing the repetition index, which
/// callers fold into their simulation seed) and aggregate.
pub fn repeat(reps: usize, mut f: impl FnMut(usize) -> f64) -> Stats {
    Stats::from_samples((0..reps).map(&mut f).collect())
}

/// One bar of a figure.
#[derive(Debug, Clone)]
pub struct Row {
    /// Bar label (platform or configuration name).
    pub label: String,
    /// Aggregated samples behind the bar.
    pub stats: Stats,
    /// Optional per-phase means (stacked-bar figures: Figs 3 and 4).
    pub breakdown: Vec<(String, f64)>,
}

impl Row {
    /// A bar with no phase breakdown.
    pub fn new(label: impl Into<String>, stats: Stats) -> Self {
        Row {
            label: label.into(),
            stats,
            breakdown: Vec::new(),
        }
    }

    /// Attach per-phase means (stacked-bar figures).
    pub fn with_breakdown(mut self, phases: Vec<(String, f64)>) -> Self {
        self.breakdown = phases;
        self
    }
}

/// Order-independent row assembly: samples accumulate under an
/// explicit `(row key, sample order)` addressing scheme instead of
/// push order, so figures come out identical however the cells that
/// produced the samples were scheduled (the scenario runner's
/// `--jobs` invariance rests on this).
///
/// `row` keys decide row order within the figure; `order` keys decide
/// sample order within a row's [`Stats`] (repetition index, so error
/// bars match a serial run sample-for-sample).
#[derive(Debug, Clone, Default)]
pub struct RowSet {
    rows: BTreeMap<u64, KeyedRow>,
}

#[derive(Debug, Clone)]
struct KeyedRow {
    label: String,
    samples: Vec<(u64, f64)>,
    breakdown: Vec<(String, f64)>,
}

impl RowSet {
    /// An empty row set.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one sample for the row keyed `row` (created with `label`
    /// on first touch), slotted at `order` within the row.
    pub fn add_sample(&mut self, row: u64, label: &str, order: u64, value: f64) {
        self.rows
            .entry(row)
            .or_insert_with(|| KeyedRow {
                label: label.to_string(),
                samples: Vec::new(),
                breakdown: Vec::new(),
            })
            .samples
            .push((order, value));
    }

    /// Attach the phase breakdown for row `row` (last write wins; the
    /// scenarios record it from repetition 0 only).
    pub fn set_breakdown(&mut self, row: u64, breakdown: Vec<(String, f64)>) {
        if let Some(r) = self.rows.get_mut(&row) {
            r.breakdown = breakdown;
        }
    }

    /// Resolve into figure rows: rows in key order, each row's samples
    /// in `order` order.
    pub fn into_rows(self) -> Vec<Row> {
        self.rows
            .into_values()
            .map(|mut r| {
                r.samples.sort_by_key(|&(order, _)| order);
                Row::new(
                    r.label,
                    Stats::from_samples(r.samples.into_iter().map(|(_, v)| v).collect()),
                )
                .with_breakdown(r.breakdown)
            })
            .collect()
    }

    /// Number of rows accumulated so far.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// Whether no sample has been recorded.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }
}

/// A renderable figure.
#[derive(Debug, Clone)]
pub struct Figure {
    /// Figure title (paper-style caption).
    pub title: String,
    /// Unit of the bar values (e.g. "run time [s]").
    pub unit: String,
    /// `true` for throughput plots (Fig 5): longer bars are better.
    pub higher_better: bool,
    /// Bars, in display order.
    pub rows: Vec<Row>,
    /// Caption footnotes.
    pub notes: Vec<String>,
}

impl Figure {
    /// An empty figure with the given caption and unit.
    pub fn new(title: impl Into<String>, unit: impl Into<String>, higher_better: bool) -> Self {
        Figure {
            title: title.into(),
            unit: unit.into(),
            higher_better,
            rows: Vec::new(),
            notes: Vec::new(),
        }
    }

    /// Append a bar.
    pub fn push(&mut self, row: Row) {
        self.rows.push(row);
    }

    /// Append a caption footnote.
    pub fn note(&mut self, s: impl Into<String>) {
        self.notes.push(s.into());
    }

    /// ASCII rendering: label, bar scaled to the max mean, mean ± std.
    pub fn render(&self) -> String {
        const WIDTH: usize = 44;
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        out.push_str(&format!(
            "({}; {} bars are better)\n",
            self.unit,
            if self.higher_better { "longer" } else { "shorter" }
        ));
        let max = self
            .rows
            .iter()
            .map(|r| r.stats.mean())
            .fold(0.0f64, f64::max)
            .max(1e-30);
        let label_w = self.rows.iter().map(|r| r.label.len()).max().unwrap_or(0);
        for row in &self.rows {
            let mean = row.stats.mean();
            let frac = (mean / max).clamp(0.0, 1.0);
            let filled = (frac * WIDTH as f64).round() as usize;
            let bar: String = "█".repeat(filled) + &"·".repeat(WIDTH - filled);
            out.push_str(&format!(
                "  {:label_w$}  {bar}  {:>10.4} ± {:.4}\n",
                row.label,
                mean,
                row.stats.std(),
            ));
            if !row.breakdown.is_empty() {
                let phases: Vec<String> = row
                    .breakdown
                    .iter()
                    .map(|(name, secs)| format!("{name} {secs:.3}"))
                    .collect();
                out.push_str(&format!(
                    "  {:label_w$}    [{}]\n",
                    "",
                    phases.join(" | ")
                ));
            }
        }
        for n in &self.notes {
            out.push_str(&format!("  note: {n}\n"));
        }
        out
    }

    /// Machine-readable form.
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("title", Value::str(self.title.clone())),
            ("unit", Value::str(self.unit.clone())),
            ("higher_better", Value::Bool(self.higher_better)),
            (
                "rows",
                Value::Arr(
                    self.rows
                        .iter()
                        .map(|r| {
                            Value::obj(vec![
                                ("label", Value::str(r.label.clone())),
                                ("mean", Value::num(r.stats.mean())),
                                ("std", Value::num(r.stats.std())),
                                ("n", Value::num(r.stats.n() as f64)),
                                (
                                    "samples",
                                    Value::Arr(
                                        r.stats.samples.iter().map(|&s| Value::num(s)).collect(),
                                    ),
                                ),
                                (
                                    "breakdown",
                                    Value::Obj(
                                        r.breakdown
                                            .iter()
                                            .map(|(k, v)| (k.clone(), Value::num(*v)))
                                            .collect(),
                                    ),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "notes",
                Value::Arr(self.notes.iter().map(|n| Value::str(n.clone())).collect()),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn repeat_collects_reps() {
        let s = repeat(5, |i| i as f64);
        assert_eq!(s.n(), 5);
        assert_eq!(s.mean(), 2.0);
    }

    #[test]
    fn render_contains_labels_and_values() {
        let mut fig = Figure::new("Fig 2: workstation", "seconds", false);
        fig.push(Row::new("native", Stats::from_samples(vec![1.0, 1.1])));
        fig.push(Row::new("docker", Stats::from_samples(vec![1.05])));
        fig.note("docker within 1% of native");
        let text = fig.render();
        assert!(text.contains("native"));
        assert!(text.contains("docker"));
        assert!(text.contains("shorter bars are better"));
        assert!(text.contains("note: docker"));
    }

    #[test]
    fn bars_scale_to_max() {
        let mut fig = Figure::new("t", "s", false);
        fig.push(Row::new("big", Stats::from_samples(vec![10.0])));
        fig.push(Row::new("small", Stats::from_samples(vec![1.0])));
        let text = fig.render();
        let big_bar = text.lines().find(|l| l.contains("big")).unwrap();
        let small_bar = text.lines().find(|l| l.contains("small")).unwrap();
        let count = |s: &str| s.chars().filter(|&c| c == '█').count();
        assert!(count(big_bar) > 8 * count(small_bar));
    }

    #[test]
    fn breakdown_renders_inline() {
        let mut fig = Figure::new("t", "s", false);
        fig.push(
            Row::new("native", Stats::from_samples(vec![3.0]))
                .with_breakdown(vec![("solve".into(), 2.0), ("io".into(), 1.0)]),
        );
        let text = fig.render();
        assert!(text.contains("solve 2.000"));
        assert!(text.contains("io 1.000"));
    }

    #[test]
    fn rowset_is_insertion_order_independent() {
        // scrambled arrival (worker completion order) vs serial arrival
        let mut scrambled = RowSet::new();
        scrambled.add_sample(1, "docker", 1, 2.1);
        scrambled.add_sample(0, "native", 1, 1.1);
        scrambled.add_sample(1, "docker", 0, 2.0);
        scrambled.add_sample(0, "native", 0, 1.0);
        scrambled.set_breakdown(0, vec![("solve".into(), 0.5)]);

        let mut serial = RowSet::new();
        serial.add_sample(0, "native", 0, 1.0);
        serial.add_sample(0, "native", 1, 1.1);
        serial.add_sample(1, "docker", 0, 2.0);
        serial.add_sample(1, "docker", 1, 2.1);
        serial.set_breakdown(0, vec![("solve".into(), 0.5)]);

        let (a, b) = (scrambled.into_rows(), serial.into_rows());
        assert_eq!(a.len(), 2);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.label, y.label);
            assert_eq!(x.stats.samples, y.stats.samples);
            assert_eq!(x.breakdown, y.breakdown);
        }
        assert_eq!(a[0].label, "native");
        assert_eq!(a[0].stats.samples, vec![1.0, 1.1]);
    }

    #[test]
    fn rowset_len_and_empty() {
        let mut rs = RowSet::new();
        assert!(rs.is_empty());
        rs.add_sample(3, "x", 0, 1.0);
        assert_eq!(rs.len(), 1);
        assert!(!rs.is_empty());
    }

    #[test]
    fn json_round_trips() {
        let mut fig = Figure::new("t", "s", true);
        fig.push(Row::new("a", Stats::from_samples(vec![1.0, 2.0])));
        let v = fig.to_json();
        let parsed = crate::util::json::parse(&v.to_pretty()).unwrap();
        assert_eq!(parsed.get("higher_better").as_bool(), Some(true));
        let rows = parsed.get("rows").as_arr().unwrap();
        assert_eq!(rows[0].get("mean").as_f64(), Some(1.5));
        assert_eq!(rows[0].get("samples").as_arr().unwrap().len(), 2);
    }
}
