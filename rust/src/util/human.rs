//! Human-scale number formatting for reports and figure tables.
//!
//! Million-node fleets overflow the `{:.1} MB` / bare-integer habits
//! the small sweeps grew up with: a 1 048 576-node cold deploy moves
//! tens of TiB of intra-cluster traffic and its row label needs digit
//! grouping to stay aligned next to "64 nodes". Byte totals keep the
//! historical decimal-MB rendering below 1 GiB (so small-fleet renders
//! stay byte-identical across the per-node and collapsed engines) and
//! switch to binary GiB/TiB above it.

/// `1048576` → `"1,048,576"`. Groups digits in threes with commas.
pub fn thousands(n: u64) -> String {
    let digits = n.to_string();
    let mut out = String::with_capacity(digits.len() + digits.len() / 3);
    let lead = digits.len() % 3;
    for (i, c) in digits.chars().enumerate() {
        if i != 0 && (i + 3 - lead) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

const GIB: u64 = 1 << 30;
const TIB: u64 = 1 << 40;

/// Byte totals for reports: decimal MB below 1 GiB (the historical
/// rendering, kept bit-for-bit), binary GiB/TiB above.
pub fn bytes(b: u64) -> String {
    if b >= TIB {
        format!("{:.2} TiB", b as f64 / TIB as f64)
    } else if b >= GIB {
        format!("{:.2} GiB", b as f64 / GIB as f64)
    } else {
        format!("{:.1} MB", b as f64 / 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thousands_groups_digits_in_threes() {
        assert_eq!(thousands(0), "0");
        assert_eq!(thousands(32), "32");
        assert_eq!(thousands(512), "512");
        assert_eq!(thousands(4096), "4,096");
        assert_eq!(thousands(16384), "16,384");
        assert_eq!(thousands(1_048_576), "1,048,576");
        assert_eq!(thousands(1_234_567_890), "1,234,567,890");
    }

    #[test]
    fn bytes_keep_the_legacy_mb_rendering_below_a_gib() {
        assert_eq!(bytes(0), "0.0 MB");
        assert_eq!(bytes(91_500_000), "91.5 MB");
        assert_eq!(bytes(GIB - 1), format!("{:.1} MB", (GIB - 1) as f64 / 1e6));
    }

    #[test]
    fn bytes_switch_to_binary_units_above_a_gib() {
        assert_eq!(bytes(GIB), "1.00 GiB");
        assert_eq!(bytes(3 * GIB / 2), "1.50 GiB");
        assert_eq!(bytes(TIB), "1.00 TiB");
        assert_eq!(bytes(45 * TIB / 10), "4.50 TiB");
    }
}
