//! A tiny declarative flag parser (the offline stand-in for `clap`).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positional
//! arguments, and generated `--help` text.  Subcommand dispatch lives in
//! `main.rs`; this handles one command's arguments.

use std::collections::BTreeMap;

/// Specification of one flag.
#[derive(Debug, Clone)]
struct FlagSpec {
    name: &'static str,
    help: &'static str,
    takes_value: bool,
    default: Option<&'static str>,
}

/// Declarative argument parser for one (sub)command.
#[derive(Debug, Clone)]
pub struct Args {
    command: &'static str,
    about: &'static str,
    flags: Vec<FlagSpec>,
    positional: Vec<(&'static str, &'static str, bool)>,
}

/// Parsed results.
#[derive(Debug, Clone)]
pub struct Parsed {
    values: BTreeMap<String, String>,
    bools: BTreeMap<String, bool>,
    positional: Vec<String>,
}

/// CLI usage error (message already formatted for the user).
#[derive(Debug)]
pub struct UsageError(
    /// The usage message to print.
    pub String,
);
impl std::fmt::Display for UsageError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}
impl std::error::Error for UsageError {}

/// Parse a human-scale count: plain digits (`4096`) or a binary
/// `k`/`m` suffix (`64k` = 64 × 1024 = 65 536, `1m` = 1 048 576).
///
/// Used by `--nodes` so million-node fleets read as `1m` instead of a
/// seven-digit literal. The multipliers are powers of 1024 — node
/// counts in the sweeps are powers of two, so `64k`/`256k`/`1m` land
/// exactly on the 65 536 / 262 144 / 1 048 576 figure rows.
pub fn parse_count(raw: &str) -> Result<usize, UsageError> {
    let s = raw.trim();
    let bad = || {
        UsageError(format!(
            "cannot parse `{raw}` as a count: accepted forms are plain integers \
             (`4096`), `<n>k` = n x 1024 (`64k` = 65536) and `<n>m` = n x 1048576 \
             (`1m` = 1048576)"
        ))
    };
    let (digits, mult): (&str, usize) = match s.char_indices().next_back() {
        Some((i, 'k')) | Some((i, 'K')) => (&s[..i], 1024),
        Some((i, 'm')) | Some((i, 'M')) => (&s[..i], 1024 * 1024),
        _ => (s, 1),
    };
    let base: usize = digits.parse().map_err(|_| bad())?;
    base.checked_mul(mult).ok_or_else(bad)
}

/// Parse a worker/domain count for `flag` (e.g. `--jobs`,
/// `--domains`): a positive integer, or the word `auto` when `auto` is
/// `Some(n)` (resolving to `n`).  Zero and garbage are rejected with a
/// usage error listing the accepted forms, the same shape
/// [`parse_count`] uses — a silent `--jobs 0` → "all cores" mapping
/// reads like a typo check that never fires.
pub fn parse_workers(flag: &str, raw: &str, auto: Option<usize>) -> Result<usize, UsageError> {
    let s = raw.trim();
    let bad = || {
        let auto_form = if auto.is_some() {
            " and `auto` = available parallelism"
        } else {
            ""
        };
        UsageError(format!(
            "cannot parse `{raw}` for --{flag}: accepted forms are positive \
             integers (`4`){auto_form}"
        ))
    };
    if let Some(n) = auto {
        if s.eq_ignore_ascii_case("auto") {
            return Ok(n);
        }
    }
    match s.parse::<usize>() {
        Ok(0) | Err(_) => Err(bad()),
        Ok(n) => Ok(n),
    }
}

impl Args {
    /// A spec for `command` with a one-line description.
    pub fn new(command: &'static str, about: &'static str) -> Self {
        Args {
            command,
            about,
            flags: Vec::new(),
            positional: Vec::new(),
        }
    }

    /// `--name <value>` with optional default.
    pub fn opt(mut self, name: &'static str, help: &'static str, default: Option<&'static str>) -> Self {
        self.flags.push(FlagSpec {
            name,
            help,
            takes_value: true,
            default,
        });
        self
    }

    /// Boolean `--name`.
    pub fn switch(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push(FlagSpec {
            name,
            help,
            takes_value: false,
            default: None,
        });
        self
    }

    /// Required positional argument.
    pub fn positional(mut self, name: &'static str, help: &'static str) -> Self {
        self.positional.push((name, help, true));
        self
    }

    /// Optional positional argument (must come after all required
    /// ones; rendered as `[name]` in the usage text).
    pub fn positional_opt(mut self, name: &'static str, help: &'static str) -> Self {
        self.positional.push((name, help, false));
        self
    }

    /// The generated `--help` text.
    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  harbor {}", self.command, self.about, self.command);
        for (p, _, required) in &self.positional {
            if *required {
                s.push_str(&format!(" <{p}>"));
            } else {
                s.push_str(&format!(" [{p}]"));
            }
        }
        if !self.flags.is_empty() {
            s.push_str(" [OPTIONS]");
        }
        if !self.positional.is_empty() {
            s.push_str("\n\nARGS:\n");
            for (p, h, required) in &self.positional {
                if *required {
                    s.push_str(&format!("  <{p}>  {h}\n"));
                } else {
                    s.push_str(&format!("  [{p}]  {h}\n"));
                }
            }
        }
        s.push_str("\n\nOPTIONS:\n");
        for f in &self.flags {
            let mut line = format!("  --{}", f.name);
            if f.takes_value {
                line.push_str(" <value>");
            }
            if let Some(d) = f.default {
                line.push_str(&format!(" (default: {d})"));
            }
            s.push_str(&format!("{line}\n      {}\n", f.help));
        }
        s
    }

    /// Parse raw args (not including the subcommand word).
    pub fn parse(&self, raw: &[String]) -> Result<Parsed, UsageError> {
        let mut values = BTreeMap::new();
        let mut bools = BTreeMap::new();
        let mut positional = Vec::new();
        for f in &self.flags {
            if let Some(d) = f.default {
                values.insert(f.name.to_string(), d.to_string());
            }
            if !f.takes_value {
                bools.insert(f.name.to_string(), false);
            }
        }
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if a == "--help" || a == "-h" {
                return Err(UsageError(self.usage()));
            }
            if let Some(name_val) = a.strip_prefix("--") {
                let (name, inline) = match name_val.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (name_val, None),
                };
                let spec = self
                    .flags
                    .iter()
                    .find(|f| f.name == name)
                    .ok_or_else(|| UsageError(format!("unknown flag --{name}\n\n{}", self.usage())))?;
                if spec.takes_value {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            raw.get(i)
                                .cloned()
                                .ok_or_else(|| UsageError(format!("--{name} needs a value")))?
                        }
                    };
                    values.insert(name.to_string(), v);
                } else {
                    if inline.is_some() {
                        return Err(UsageError(format!("--{name} takes no value")));
                    }
                    bools.insert(name.to_string(), true);
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        let required = self.positional.iter().filter(|(_, _, r)| *r).count();
        if positional.len() < required {
            return Err(UsageError(format!(
                "missing required argument <{}>\n\n{}",
                self.positional[positional.len()].0,
                self.usage()
            )));
        }
        Ok(Parsed {
            values,
            bools,
            positional,
        })
    }
}

impl Parsed {
    /// The value of option `--name`, if given or defaulted.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    /// Value of a defaulted flag (panics if the flag was not declared
    /// with a default — a programming error, not a user error).
    pub fn req(&self, name: &str) -> &str {
        self.get(name)
            .unwrap_or_else(|| panic!("flag --{name} has no value or default"))
    }

    /// Whether switch `--name` was passed.
    pub fn flag(&self, name: &str) -> bool {
        self.bools.get(name).copied().unwrap_or(false)
    }

    /// The `idx`-th positional argument.
    pub fn pos(&self, idx: usize) -> &str {
        &self.positional[idx]
    }

    /// The `idx`-th positional argument, if given (optional positionals).
    pub fn pos_opt(&self, idx: usize) -> Option<&str> {
        self.positional.get(idx).map(|s| s.as_str())
    }

    /// Parse the value of `--name` into `T`.
    pub fn parse_num<T: std::str::FromStr>(&self, name: &str) -> Result<T, UsageError> {
        let raw = self
            .get(name)
            .ok_or_else(|| UsageError(format!("--{name} is required")))?;
        raw.parse()
            .map_err(|_| UsageError(format!("--{name}: cannot parse `{raw}`")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args() -> Args {
        Args::new("bench", "run a figure benchmark")
            .opt("reps", "repetitions", Some("5"))
            .opt("out", "output path", None)
            .switch("json", "emit JSON")
            .positional("figure", "which figure")
    }

    fn raw(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_everything() {
        let p = args()
            .parse(&raw(&["fig2", "--reps", "3", "--json", "--out=report.json"]))
            .unwrap();
        assert_eq!(p.pos(0), "fig2");
        assert_eq!(p.req("reps"), "3");
        assert_eq!(p.get("out"), Some("report.json"));
        assert!(p.flag("json"));
        assert_eq!(p.parse_num::<usize>("reps").unwrap(), 3);
    }

    #[test]
    fn defaults_apply() {
        let p = args().parse(&raw(&["fig3"])).unwrap();
        assert_eq!(p.req("reps"), "5");
        assert_eq!(p.get("out"), None);
        assert!(!p.flag("json"));
    }

    #[test]
    fn missing_positional_is_an_error() {
        let e = args().parse(&raw(&["--reps", "2"])).unwrap_err();
        assert!(e.0.contains("<figure>"));
    }

    #[test]
    fn unknown_flag_is_an_error() {
        let e = args().parse(&raw(&["fig2", "--bogus"])).unwrap_err();
        assert!(e.0.contains("--bogus"));
    }

    #[test]
    fn missing_value_is_an_error() {
        let e = args().parse(&raw(&["fig2", "--reps"])).unwrap_err();
        assert!(e.0.contains("needs a value"));
    }

    #[test]
    fn bad_number_is_an_error() {
        let p = args().parse(&raw(&["fig2", "--reps", "many"])).unwrap();
        assert!(p.parse_num::<usize>("reps").is_err());
    }

    #[test]
    fn optional_positional_may_be_absent() {
        let spec = Args::new("bench", "run a figure benchmark")
            .switch("list", "list scenarios")
            .positional_opt("figure", "which figure");
        let without = spec.parse(&raw(&["--list"])).unwrap();
        assert!(without.flag("list"));
        assert_eq!(without.pos_opt(0), None);
        let with = spec.parse(&raw(&["fig2"])).unwrap();
        assert_eq!(with.pos_opt(0), Some("fig2"));
        assert!(spec.usage().contains("[figure]"));
    }

    #[test]
    fn parse_count_accepts_plain_integers_and_binary_suffixes() {
        assert_eq!(parse_count("4096").unwrap(), 4096);
        assert_eq!(parse_count(" 512 ").unwrap(), 512);
        assert_eq!(parse_count("64k").unwrap(), 65_536);
        assert_eq!(parse_count("256K").unwrap(), 262_144);
        assert_eq!(parse_count("1m").unwrap(), 1_048_576);
        assert_eq!(parse_count("4M").unwrap(), 4_194_304);
    }

    #[test]
    fn parse_count_rejects_garbage_with_the_accepted_forms() {
        for bad in ["", "k", "1.5k", "64kb", "ten", "-4", "1e6"] {
            let e = parse_count(bad).unwrap_err();
            assert!(e.0.contains("accepted forms"), "error for `{bad}`: {e}");
            assert!(e.0.contains("64k"), "error lists examples: {e}");
        }
        // overflow on the multiply is an error, not a wrap
        assert!(parse_count("99999999999999999m").is_err());
    }

    #[test]
    fn parse_workers_accepts_positive_counts_and_auto() {
        assert_eq!(parse_workers("jobs", "4", Some(8)).unwrap(), 4);
        assert_eq!(parse_workers("jobs", " 1 ", Some(8)).unwrap(), 1);
        assert_eq!(parse_workers("jobs", "auto", Some(8)).unwrap(), 8);
        assert_eq!(parse_workers("jobs", "AUTO", Some(8)).unwrap(), 8);
        assert_eq!(parse_workers("domains", "2", None).unwrap(), 2);
    }

    #[test]
    fn parse_workers_rejects_zero_and_garbage_with_the_accepted_forms() {
        for bad in ["0", "", "many", "-1", "1.5", "4k"] {
            let e = parse_workers("jobs", bad, Some(8)).unwrap_err();
            assert!(e.0.contains("--jobs"), "error names the flag: {e}");
            assert!(e.0.contains("accepted forms"), "error for `{bad}`: {e}");
            assert!(e.0.contains("auto"), "auto is offered when available: {e}");
        }
        // without an auto resolution, `auto` is garbage too
        let e = parse_workers("domains", "auto", None).unwrap_err();
        assert!(e.0.contains("--domains"), "{e}");
        assert!(!e.0.contains("`auto` ="), "auto not offered: {e}");
        assert!(parse_workers("domains", "0", None).is_err());
    }

    #[test]
    fn help_returns_usage() {
        let e = args().parse(&raw(&["--help"])).unwrap_err();
        assert!(e.0.contains("USAGE"));
        assert!(e.0.contains("--reps"));
    }
}
