//! Miniature property-testing loop (the offline stand-in for `proptest`).
//!
//! [`run`] drives a property over `cases` randomly generated inputs; on
//! failure it reports the seed and the case index so the exact input can
//! be regenerated.  Generators are plain closures over [`Gen`], which
//! wraps the crate RNG with convenience samplers.

use super::rng::Xoshiro256;

/// Input generator handle passed to property closures.
pub struct Gen {
    rng: Xoshiro256,
}

impl Gen {
    /// Uniform `usize` in `lo..=hi`.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below(hi - lo + 1)
    }

    /// Uniform `u64` in `lo..=hi`.
    pub fn u64_in(&mut self, lo: u64, hi: u64) -> u64 {
        lo + (self.rng.next_u64() % (hi - lo + 1))
    }

    /// Uniform `f64` in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    /// A fair coin.
    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// A vector of `len` items drawn by `f`.
    pub fn vec<T>(&mut self, len: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        (0..len).map(|_| f(self)).collect()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.rng.below(items.len())]
    }

    /// An ASCII identifier-ish string.
    pub fn ident(&mut self, max_len: usize) -> String {
        let len = self.usize_in(1, max_len);
        (0..len)
            .map(|_| {
                let alphabet = b"abcdefghijklmnopqrstuvwxyz0123456789_-";
                alphabet[self.rng.below(alphabet.len())] as char
            })
            .collect()
    }
}

/// Run `property` over `cases` random inputs. Panics (test failure) with
/// the reproducing seed on the first violated case.
pub fn run(name: &str, cases: usize, mut property: impl FnMut(&mut Gen) -> Result<(), String>) {
    run_seeded(name, 0xda7a_5eed, cases, &mut property);
}

/// As [`run`] with an explicit base seed (used to reproduce failures).
pub fn run_seeded(
    name: &str,
    base_seed: u64,
    cases: usize,
    property: &mut impl FnMut(&mut Gen) -> Result<(), String>,
) {
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64);
        let mut g = Gen {
            rng: Xoshiro256::seed_from_u64(seed),
        };
        if let Err(msg) = property(&mut g) {
            panic!(
                "property `{name}` failed on case {case} (seed {seed:#x}): {msg}\n\
                 reproduce with run_seeded(\"{name}\", {seed:#x}, 1, ...)"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        run("tautology", 50, |g| {
            count += 1;
            let x = g.usize_in(0, 10);
            if x <= 10 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property `falsum` failed")]
    fn failing_property_panics_with_seed() {
        run("falsum", 10, |g| {
            let x = g.usize_in(0, 100);
            if x < 101 {
                Err(format!("x = {x}"))
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn generators_respect_bounds() {
        run("bounds", 200, |g| {
            let a = g.usize_in(3, 7);
            let b = g.f64_in(-1.0, 1.0);
            let c = g.u64_in(10, 20);
            let s = g.ident(12);
            if !(3..=7).contains(&a) {
                return Err(format!("usize {a}"));
            }
            if !(-1.0..1.0).contains(&b) {
                return Err(format!("f64 {b}"));
            }
            if !(10..=20).contains(&c) {
                return Err(format!("u64 {c}"));
            }
            if s.is_empty() || s.len() > 12 {
                return Err(format!("ident {s:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn vec_and_choose() {
        run("vec-choose", 50, |g| {
            let v = g.vec(5, |g| g.usize_in(0, 9));
            if v.len() != 5 {
                return Err("len".into());
            }
            let picked = *g.choose(&v);
            if !v.contains(&picked) {
                return Err("choose out of set".into());
            }
            Ok(())
        });
    }
}
