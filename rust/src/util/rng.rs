//! xoshiro256** — the crate's pseudo-random source.
//!
//! Small, fast, and statistically solid for simulation noise (Blackman &
//! Vigna 2018).  Seeded through SplitMix64 so even adjacent integer
//! seeds give uncorrelated streams.

/// FNV-1a 64-bit fold over `bytes`, from the standard offset basis —
/// the crate's one definition of the hash (stream-label folding in
/// [`SimRng`](crate::des::SimRng), `(scenario, cell)` seed derivation
/// in [`cell_seed`](crate::scenario::cell_seed)).
pub fn fnv1a(bytes: impl IntoIterator<Item = u8>) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// xoshiro256** PRNG.
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 (any u64, including 0, is a fine seed).
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Xoshiro256 {
            s: [next(), next(), next(), next()],
        }
    }

    /// Next 64 random bits.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Uniform usize in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // multiply-shift; bias negligible for simulation use
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Xoshiro256::seed_from_u64(12345);
        let mut b = Xoshiro256::seed_from_u64(12345);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn adjacent_seeds_differ() {
        let mut a = Xoshiro256::seed_from_u64(1);
        let mut b = Xoshiro256::seed_from_u64(2);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Xoshiro256::seed_from_u64(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_covers_the_interval() {
        let mut r = Xoshiro256::seed_from_u64(3);
        let xs: Vec<f64> = (0..10_000).map(|_| r.next_f64()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
        assert!(xs.iter().any(|&x| x < 0.01));
        assert!(xs.iter().any(|&x| x > 0.99));
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut r = Xoshiro256::seed_from_u64(11);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5)] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn zero_seed_is_fine() {
        let mut r = Xoshiro256::seed_from_u64(0);
        assert_ne!(r.next_u64(), 0);
        assert_ne!(r.next_u64(), r.next_u64());
    }
}
