//! Self-contained utilities.
//!
//! This build environment is fully offline with only the `xla` crate's
//! dependency closure cached, so the staples a Rust project would import
//! are implemented here instead:
//!
//! * [`json`] — a small, strict JSON parser + serializer (replaces
//!   `serde_json`); used for the AOT artifact manifest, the calibration
//!   table, and machine-readable benchmark reports.
//! * [`rng`] — xoshiro256** PRNG (replaces `rand`); seeds the
//!   deterministic simulation noise streams.
//! * [`cli`] — a tiny declarative flag parser (replaces `clap`).
//! * [`proptest`] — a miniature property-testing loop with failure-case
//!   reporting (replaces `proptest` for our invariant tests).
//! * [`human`] — digit grouping and byte humanization for reports
//!   (replaces `humansize`/`num-format`).

pub mod cli;
pub mod human;
pub mod json;
pub mod proptest;
pub mod rng;
