//! Minimal JSON: a strict recursive-descent parser and a serializer.
//!
//! Covers the JSON this crate produces and consumes (the AOT manifest,
//! calibration tables, benchmark reports): objects, arrays, strings with
//! the standard escapes, f64 numbers, booleans, null.  Not a general
//! replacement for serde — just enough, tested.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Objects use `BTreeMap` for deterministic serialization.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// A number (always stored as `f64`).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Value>),
    /// An object (sorted keys).
    Obj(BTreeMap<String, Value>),
}

impl Value {
    // ---- typed accessors -------------------------------------------------

    /// The number, if this is a `Num`.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The number as an exact unsigned integer, if it is one.
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as u64)
    }

    /// The string, if this is a `Str`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The boolean, if this is a `Bool`.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The elements, if this is an `Arr`.
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// The key→value map, if this is an `Obj`.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]` access; `Null` when missing or not an object.
    pub fn get(&self, key: &str) -> &Value {
        static NULL: Value = Value::Null;
        self.as_obj().and_then(|o| o.get(key)).unwrap_or(&NULL)
    }

    // ---- constructors ----------------------------------------------------

    /// An object from `(key, value)` pairs.
    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// A number value.
    pub fn num(n: f64) -> Value {
        Value::Num(n)
    }

    /// A string value.
    pub fn str(s: impl Into<String>) -> Value {
        Value::Str(s.into())
    }

    // ---- serialization ---------------------------------------------------

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with 1-space indent (matches `json.dump(indent=1)`).
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(1), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let (nl, pad, pad_in) = match indent {
            Some(w) => (
                "\n",
                " ".repeat(w * depth),
                " ".repeat(w * (depth + 1)),
            ),
            None => ("", String::new(), String::new()),
        };
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Value::Str(s) => write_escaped(out, s),
            Value::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push(']');
            }
            Value::Obj(o) => {
                if o.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    /// Byte offset of the error.
    pub offset: usize,
    /// What went wrong.
    pub message: String,
}
impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}
impl std::error::Error for JsonError {}

/// Parse a complete JSON document (trailing whitespace allowed).
pub fn parse(text: &str) -> Result<Value, JsonError> {
    let mut p = Parser {
        bytes: text.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing garbage"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, message: &str) -> JsonError {
        JsonError {
            offset: self.pos,
            message: message.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{word}`")))
        }
    }

    fn value(&mut self) -> Result<Value, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Value, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(map));
                }
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(items));
                }
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{0008}'),
                        b'f' => s.push('\u{000c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            // BMP only (no surrogate pairs) — enough here
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // copy one UTF-8 char
                    let start = self.pos;
                    let text = std::str::from_utf8(&self.bytes[start..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = text.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("-2.5e2").unwrap(), Value::Num(-250.0));
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").as_str(), Some("x"));
        let arr = v.get("a").as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[1].get("b"), &Value::Null);
    }

    #[test]
    fn round_trips_the_manifest_shape() {
        let src = r#"{"format": "hlo-text/return-tuple", "entries": [
            {"name": "dot_L4096", "inputs": [{"shape": [4096], "dtype": "float32"}],
             "outputs": [{"shape": [1], "dtype": "float32"}]}]}"#;
        let v = parse(src).unwrap();
        let entry = &v.get("entries").as_arr().unwrap()[0];
        assert_eq!(entry.get("name").as_str(), Some("dot_L4096"));
        let shape = entry.get("inputs").as_arr().unwrap()[0].get("shape");
        assert_eq!(shape.as_arr().unwrap()[0].as_u64(), Some(4096));
        // and re-serialize + re-parse is stable
        let again = parse(&v.to_string()).unwrap();
        assert_eq!(v, again);
    }

    #[test]
    fn escapes_round_trip() {
        let s = Value::Str("a\"b\\c\nd\te\u{0007}".into());
        let text = s.to_string();
        assert_eq!(parse(&text).unwrap(), s);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""é""#).unwrap(), Value::Str("é".into()));
    }

    #[test]
    fn integers_serialize_without_point() {
        assert_eq!(Value::Num(42.0).to_string(), "42");
        assert_eq!(Value::Num(1.5).to_string(), "1.5");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("01x").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("true false").is_err());
    }

    #[test]
    fn error_carries_offset() {
        let e = parse("[1, @]").unwrap_err();
        assert_eq!(e.offset, 4);
        assert!(e.to_string().contains("byte 4"));
    }

    #[test]
    fn pretty_is_parseable_and_indented() {
        let v = Value::obj(vec![("x", Value::Arr(vec![Value::Num(1.0)]))]);
        let pretty = v.to_pretty();
        assert!(pretty.contains("\n \"x\""));
        assert_eq!(parse(&pretty).unwrap(), v);
    }

    #[test]
    fn get_on_non_object_is_null() {
        assert_eq!(parse("[1]").unwrap().get("x"), &Value::Null);
    }

    #[test]
    fn as_u64_rejects_fractions_and_negatives() {
        assert_eq!(Value::Num(3.0).as_u64(), Some(3));
        assert_eq!(Value::Num(3.5).as_u64(), None);
        assert_eq!(Value::Num(-1.0).as_u64(), None);
    }
}
