//! Machines, nodes, and job launch.
//!
//! Two machine models cover the paper's testbeds: a 16-core Xeon
//! workstation (Fig 2, Fig 5a) and *Edison*, the NERSC Cray XC30 used
//! for Figs 3, 4, 5b (24 cores/node, Aries interconnect, Lustre).  The
//! SLURM-like [`launch`] maps MPI ranks onto nodes block-wise — one rank
//! per core, exactly as `srun -n N` does with default placement.


use crate::des::Duration;
use crate::net::FabricKind;

/// Static description of a machine (the "testbed").
#[derive(Debug, Clone)]
pub struct MachineSpec {
    /// Machine name ("workstation", "edison").
    pub name: String,
    /// Physical cores per node (= max ranks per node).
    pub cores_per_node: usize,
    /// Node count of the whole machine (a job uses a slice).
    pub num_nodes: usize,
    /// The fabric the *system* MPI library drives.
    pub host_fabric: FabricKind,
    /// Whether the system MPI exposes an MPICH-compatible ABI that a
    /// container can link against at runtime (the Cray MPI does).
    pub system_mpi_abi_compatible: bool,
    /// Run-to-run multiplicative compute jitter (gives the error bars).
    pub compute_jitter: f64,
    /// Native filesystem: `true` = parallel (Lustre-like), else local.
    pub parallel_fs: bool,
    /// Time for the batch system to start one process on a node.
    pub process_spawn: DurationMs,
}

/// Serde-friendly milliseconds wrapper.
#[derive(Debug, Clone, Copy)]
pub struct DurationMs(
    /// Milliseconds.
    pub f64,
);

impl DurationMs {
    /// Convert to a virtual-time span.
    pub fn duration(self) -> Duration {
        Duration::from_secs_f64(self.0 / 1e3)
    }
}

impl MachineSpec {
    /// The Fig 2 workstation: 2x E5-2670 (16 cores), 128 GB, local SSD.
    pub fn workstation() -> Self {
        MachineSpec {
            name: "workstation".into(),
            cores_per_node: 16,
            num_nodes: 1,
            host_fabric: FabricKind::SharedMem,
            system_mpi_abi_compatible: true,
            compute_jitter: 0.01,
            parallel_fs: false,
            process_spawn: DurationMs(5.0),
        }
    }

    /// Edison: Cray XC30, 2x E5-2695v2 per node (24 cores), Aries,
    /// Lustre scratch.  5576 nodes in the real machine; we only model
    /// the slice a job allocates.
    pub fn edison() -> Self {
        MachineSpec {
            name: "edison".into(),
            cores_per_node: 24,
            num_nodes: 5576,
            host_fabric: FabricKind::Aries,
            system_mpi_abi_compatible: true,
            compute_jitter: 0.015,
            parallel_fs: true,
            process_spawn: DurationMs(20.0),
        }
    }

    /// Cores across the whole machine.
    pub fn total_cores(&self) -> usize {
        self.cores_per_node * self.num_nodes
    }
}

/// A job's rank → node placement.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Allocation {
    /// Identity of the machine the job landed on.
    pub machine: MachineSpec_,
    /// `node_of[rank]` = node index.
    pub node_of: Vec<usize>,
    /// Number of nodes the block placement touched.
    pub nodes_used: usize,
}

// The allocation embeds a trimmed copy of the machine identity to avoid
// dragging lifetimes through every simulation structure.
#[derive(Debug, Clone, PartialEq, Eq)]
/// Trimmed machine identity embedded in an [`Allocation`].
pub struct MachineSpec_ {
    /// Machine name.
    pub name: String,
    /// Cores per node (decides same-node placement).
    pub cores_per_node: usize,
}

/// Why a launch was refused (Display/Error hand-rolled; the crate keeps
/// its dependency set small rather than pulling in `thiserror`).
#[derive(Debug)]
pub enum LaunchError {
    /// More cores requested than the machine has.
    TooLarge {
        /// Cores the job asked for.
        requested: usize,
        /// Cores the machine has.
        available: usize,
        /// Machine that refused.
        machine: String,
    },
    /// A job of zero ranks makes no sense.
    ZeroRanks,
}

impl std::fmt::Display for LaunchError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LaunchError::TooLarge {
                requested,
                available,
                machine,
            } => write!(
                f,
                "job needs {requested} cores but {machine} has {available}"
            ),
            LaunchError::ZeroRanks => write!(f, "zero ranks requested"),
        }
    }
}
impl std::error::Error for LaunchError {}

/// `srun -n ranks`: block placement, one rank per core.
pub fn launch(machine: &MachineSpec, ranks: usize) -> Result<Allocation, LaunchError> {
    if ranks == 0 {
        return Err(LaunchError::ZeroRanks);
    }
    if ranks > machine.total_cores() {
        return Err(LaunchError::TooLarge {
            requested: ranks,
            available: machine.total_cores(),
            machine: machine.name.clone(),
        });
    }
    let node_of: Vec<usize> = (0..ranks).map(|r| r / machine.cores_per_node).collect();
    let nodes_used = node_of.last().map(|&n| n + 1).unwrap_or(0);
    Ok(Allocation {
        machine: MachineSpec_ {
            name: machine.name.clone(),
            cores_per_node: machine.cores_per_node,
        },
        node_of,
        nodes_used,
    })
}

impl Allocation {
    /// Number of ranks in the job.
    pub fn ranks(&self) -> usize {
        self.node_of.len()
    }

    /// Whether ranks `a` and `b` share a node.
    pub fn same_node(&self, a: usize, b: usize) -> bool {
        self.node_of[a] == self.node_of[b]
    }

    /// Ranks hosted on `node`.
    pub fn ranks_on_node(&self, node: usize) -> impl Iterator<Item = usize> + '_ {
        self.node_of
            .iter()
            .enumerate()
            .filter(move |(_, &n)| n == node)
            .map(|(r, _)| r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn workstation_is_single_node() {
        let m = MachineSpec::workstation();
        assert_eq!(m.total_cores(), 16);
        let a = launch(&m, 16).unwrap();
        assert_eq!(a.nodes_used, 1);
        assert!(a.same_node(0, 15));
    }

    #[test]
    fn edison_block_placement() {
        let m = MachineSpec::edison();
        let a = launch(&m, 192).unwrap();
        assert_eq!(a.nodes_used, 8);
        assert_eq!(a.node_of[0], 0);
        assert_eq!(a.node_of[23], 0);
        assert_eq!(a.node_of[24], 1);
        assert_eq!(a.node_of[191], 7);
        assert!(a.same_node(0, 23));
        assert!(!a.same_node(23, 24));
    }

    #[test]
    fn partial_last_node() {
        let m = MachineSpec::edison();
        let a = launch(&m, 30).unwrap();
        assert_eq!(a.nodes_used, 2);
        assert_eq!(a.ranks_on_node(1).count(), 6);
    }

    #[test]
    fn oversubscription_rejected() {
        let m = MachineSpec::workstation();
        let err = launch(&m, 17).unwrap_err();
        assert!(matches!(err, LaunchError::TooLarge { .. }));
        assert!(err.to_string().contains("17"));
    }

    #[test]
    fn zero_ranks_rejected() {
        assert!(matches!(
            launch(&MachineSpec::workstation(), 0),
            Err(LaunchError::ZeroRanks)
        ));
    }

    #[test]
    fn ranks_on_node_enumerates() {
        let m = MachineSpec::edison();
        let a = launch(&m, 48).unwrap();
        let on0: Vec<_> = a.ranks_on_node(0).collect();
        assert_eq!(on0, (0..24).collect::<Vec<_>>());
    }
}
