#!/usr/bin/env bash
# Determinism render-diff gate, shared by CI and local runs.
#
# Every scenario below must render byte-identically for every
# --domains x --jobs combination (the conservative-parallel-DES and
# matrix-parallelism contracts), and rerun-stably at the widest
# setting.  diff(1) on the CLI output is the bluntest possible check —
# exactly what we want: any drift in a figure, note, or stat line
# fails the gate.
#
# One scenario per entry: "<scenario> [extra flags...]".  Add new
# scenarios here, not as copy-pasted workflow steps.
set -euo pipefail
cd "$(dirname "$0")/../rust"

SCENARIOS=(
  "chaos-canary --nodes 512"
  "registry-storm --nodes 4"
  "version-churn"
  "dep-storm --nodes 16,64"
  "fig1-scale --nodes 4096"
  "build-farm"
)

out=$(mktemp -d)
trap 'rm -rf "$out"' EXIT

for spec in ${SCENARIOS[@]+"${SCENARIOS[@]}"}; do
  name=${spec%% *}
  ref="$out/$name-ref.txt"
  # shellcheck disable=SC2086  # $spec is a scenario plus its flags
  cargo run --release -q -- bench $spec --domains 1 --jobs 1 > "$ref"
  for domains in 1 2 4; do
    for jobs in 1 4; do
      if [ "$domains" -eq 1 ] && [ "$jobs" -eq 1 ]; then continue; fi
      got="$out/$name-d$domains-j$jobs.txt"
      # shellcheck disable=SC2086
      cargo run --release -q -- bench $spec --domains "$domains" --jobs "$jobs" > "$got"
      if ! diff "$ref" "$got"; then
        echo "$name diverged at --domains $domains --jobs $jobs" >&2
        exit 1
      fi
    done
  done
  # rerun stability at the widest setting
  # shellcheck disable=SC2086
  cargo run --release -q -- bench $spec --domains 4 --jobs 4 > "$out/$name-again.txt"
  diff "$out/$name-d4-j4.txt" "$out/$name-again.txt"
  echo "$name: byte-identical across --domains {1,2,4} x --jobs {1,4}, rerun-stable"
done

# Golden gate from the node-class collapsing tentpole: the collapsed
# fig1-scale engine (the default) must render byte-identically to the
# per-node reference walk at a size the reference can still afford.
cargo run --release -q -- bench fig1-scale --nodes 4096 --jobs 1 > "$out/fig1-collapsed.txt"
cargo run --release -q -- bench fig1-scale --nodes 4096 --jobs 1 --per-rank > "$out/fig1-per-rank.txt"
diff "$out/fig1-collapsed.txt" "$out/fig1-per-rank.txt"
echo "fig1-scale: collapsed engine matches the per-node reference at 4096 nodes"
